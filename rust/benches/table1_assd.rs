//! Table 1 — Comparison of Speculative and Sequential Decoding.
//!
//! Paper setup: 640 WikiText chunks of 512 tokens, 95% masked, k = 5;
//! samplers Sequential / ASSD(N-Gram) / ASSD(Self); columns Gen PPL,
//! Entropy, Model NFE, Aux NFE, Time.
//!
//! Our setup (docs/ARCHITECTURE.md): packed synthetic-prose chunks of 128 tokens,
//! 95% masked, k = 5, FT checkpoint; the judge is the same FT model's
//! one-pass joint density (fixed across samplers). Scale with
//! ASARM_BENCH_SEQS (default 8).
//!
//! Run: `cargo bench --bench table1_assd`

use asarm::coordinator::SamplerKind;
use asarm::eval::harness::{masked_prose_workload, run_sampler};
use asarm::eval::ppl::{generative_perplexity, shannon_entropy};
use asarm::runtime::{Engine, XlaEngine};
use asarm::util::bench::Table;
use asarm::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = format!("{artifacts}/ckpt_stories_ft.bin");
    if !std::path::Path::new(&ckpt).exists() {
        eprintln!("table1: missing {ckpt}; run `make models` first");
        return Ok(());
    }
    let n_seqs: usize = std::env::var("ASARM_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let k = 5;
    let engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ckpt)))?;
    let items = masked_prose_workload(engine.seq_len(), n_seqs, 0.95, 42);
    eprintln!(
        "table1: {} sequences of {} tokens, 95% masked, k={k}",
        items.len(),
        engine.seq_len()
    );

    let samplers = [
        ("Sequential", SamplerKind::Sequential),
        ("ASSD (N-Gram)", SamplerKind::AssdNgram),
        ("ASSD (Self)", SamplerKind::Assd),
    ];
    let mut table = Table::new(&[
        "Sampler",
        "Gen PPL",
        "Entropy",
        "Model NFE",
        "Aux NFE",
        "Time (s)",
        "Tok/iter",
    ]);
    for (label, sampler) in samplers {
        let mut ppl = Summary::new();
        let mut ent = Summary::new();
        let mut nfe = Summary::new();
        let mut aux = Summary::new();
        let mut time = Summary::new();
        let mut tpi = Summary::new();
        for (i, item) in items.iter().enumerate() {
            let (out, secs) = run_sampler(&engine, item, sampler, k, 32, 1.0, 1000 + i as u64)?;
            let gp = generative_perplexity(&engine, &out.tokens, 1)?;
            ppl.push(gp);
            ent.push(shannon_entropy(&out.tokens));
            nfe.push(out.model_nfe as f64);
            aux.push(out.aux_nfe as f64);
            time.push(secs);
            let n_targets = item.ord.n_targets();
            if out.iterations > 0 {
                tpi.push(out.tokens_per_iteration(n_targets));
            }
        }
        table.row(&[
            label.to_string(),
            ppl.fmt_pm(),
            ent.fmt_pm(),
            nfe.fmt_pm(),
            aux.fmt_pm(),
            time.fmt_pm(),
            format!("{:.2}", tpi.mean()),
        ]);
    }
    println!("\n=== Table 1: Speculative vs Sequential Decoding (FT model) ===");
    table.print();
    println!(
        "(paper, 110M/512tok: Sequential 486 NFE/18.2s; ASSD(N-Gram) 422+422 aux/16.8s; \
         ASSD(Self) 434/16.5s; PPL & entropy statistically equal across samplers)"
    );
    Ok(())
}
