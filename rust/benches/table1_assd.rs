//! Table 1 — Comparison of Speculative and Sequential Decoding, extended
//! with the drafter sweep (the draft subsystem's ablation axis).
//!
//! Paper setup: 640 WikiText chunks of 512 tokens, 95% masked, k = 5;
//! samplers Sequential / ASSD(N-Gram) / ASSD(Self); columns Gen PPL,
//! Entropy, Model NFE, Aux NFE, Time.
//!
//! Our setup (docs/ARCHITECTURE.md): packed synthetic-prose chunks of 128
//! tokens, 95% masked, k = 5, FT checkpoint; the judge is the same FT
//! model's one-pass joint density (fixed across samplers). On top of the
//! paper's three rows we sweep the draft subsystem: every drafter kind
//! (self / bigram / lookup), fixed vs adaptive window, with NFE/token and
//! acceptance-rate columns. Scale with ASARM_BENCH_SEQS (default 8).
//!
//! Run: `cargo bench --bench table1_assd`
//! Smoke (no artifacts; analytic mock engine): `make bench-smoke`
//! (ASARM_BENCH_MOCK=1).

use asarm::coordinator::SamplerKind;
use asarm::draft::{DraftKind, DraftOptions};
use asarm::eval::harness::{masked_prose_workload, run_sampler_with};
use asarm::eval::ppl::{generative_perplexity, shannon_entropy};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::{Engine, XlaEngine};
use asarm::util::bench::Table;
use asarm::util::stats::Summary;

fn load_engine() -> anyhow::Result<Option<Box<dyn Engine>>> {
    if std::env::var("ASARM_BENCH_MOCK").is_ok() {
        eprintln!("table1: ASARM_BENCH_MOCK set — using the analytic mock engine");
        return Ok(Some(Box::new(MockEngine::new(7, 64, 258, 1.0))));
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = format!("{artifacts}/ckpt_stories_ft.bin");
    if !std::path::Path::new(&ckpt).exists() {
        eprintln!("table1: missing {ckpt}; run `make models` first (or ASARM_BENCH_MOCK=1)");
        return Ok(None);
    }
    let engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ckpt)))?;
    Ok(Some(Box::new(engine)))
}

fn main() -> anyhow::Result<()> {
    let Some(engine) = load_engine()? else {
        return Ok(());
    };
    let engine: &dyn Engine = engine.as_ref();
    let n_seqs: usize = std::env::var("ASARM_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let k = 5;
    let items = masked_prose_workload(engine.seq_len(), n_seqs, 0.95, 42);
    eprintln!(
        "table1: {} sequences of {} tokens, 95% masked, k={k}",
        items.len(),
        engine.seq_len()
    );

    // The paper's three rows, then the drafter sweep.
    let fixed = |kind| DraftOptions {
        kind,
        max_len: k,
        adaptive: false,
    };
    let adaptive = |kind| DraftOptions {
        kind,
        max_len: k,
        adaptive: true,
    };
    let rows: [(&str, SamplerKind, DraftOptions); 7] = [
        ("Sequential", SamplerKind::Sequential, fixed(DraftKind::SelfModel)),
        ("ASSD (N-Gram)", SamplerKind::Assd, fixed(DraftKind::Bigram)),
        ("ASSD (Self)", SamplerKind::Assd, fixed(DraftKind::SelfModel)),
        ("ASSD (Lookup)", SamplerKind::Assd, fixed(DraftKind::Lookup)),
        ("ASSD (N-Gram, adaptive)", SamplerKind::Assd, adaptive(DraftKind::Bigram)),
        ("ASSD (Self, adaptive)", SamplerKind::Assd, adaptive(DraftKind::SelfModel)),
        ("ASSD (Lookup, adaptive)", SamplerKind::Assd, adaptive(DraftKind::Lookup)),
    ];
    let mut table = Table::new(&[
        "Sampler",
        "Gen PPL",
        "Entropy",
        "Model NFE",
        "Aux NFE",
        "NFE/tok",
        "Accept",
        "Time (s)",
        "Tok/iter",
    ]);
    let mut nfe_per_tok: Vec<(String, f64)> = vec![];
    for (label, sampler, draft) in rows {
        let mut ppl = Summary::new();
        let mut ent = Summary::new();
        let mut nfe = Summary::new();
        let mut aux = Summary::new();
        let mut npt = Summary::new();
        let mut acc = Summary::new();
        let mut time = Summary::new();
        let mut tpi = Summary::new();
        for (i, item) in items.iter().enumerate() {
            let (out, secs) =
                run_sampler_with(engine, item, sampler, draft, 32, 1.0, 1000 + i as u64)?;
            let gp = generative_perplexity(engine, &out.tokens, 1)?;
            ppl.push(gp);
            ent.push(shannon_entropy(&out.tokens));
            nfe.push(out.model_nfe as f64);
            aux.push(out.aux_nfe as f64);
            let n_targets = item.ord.n_targets();
            npt.push(out.model_nfe as f64 / n_targets.max(1) as f64);
            acc.push(out.acceptance_rate());
            time.push(secs);
            if out.iterations > 0 {
                tpi.push(out.tokens_per_iteration(n_targets));
            }
        }
        nfe_per_tok.push((label.to_string(), npt.mean()));
        table.row(&[
            label.to_string(),
            ppl.fmt_pm(),
            ent.fmt_pm(),
            nfe.fmt_pm(),
            aux.fmt_pm(),
            format!("{:.3}", npt.mean()),
            format!("{:.3}", acc.mean()),
            time.fmt_pm(),
            format!("{:.2}", tpi.mean()),
        ]);
    }
    println!("\n=== Table 1: Speculative vs Sequential Decoding + drafter sweep ===");
    table.print();
    println!(
        "(paper, 110M/512tok: Sequential 486 NFE/18.2s; ASSD(N-Gram) 422+422 aux/16.8s; \
         ASSD(Self) 434/16.5s; PPL & entropy statistically equal across samplers)"
    );
    // Acceptance check for the adaptive controller: growing windows must
    // convert verify forwards into more tokens than the fixed bigram
    // baseline does.
    let get = |label: &str| {
        nfe_per_tok
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    };
    let fixed_bigram = get("ASSD (N-Gram)");
    let adaptive_bigram = get("ASSD (N-Gram, adaptive)");
    println!(
        "adaptive check: bigram NFE/token fixed {fixed_bigram:.3} vs adaptive \
         {adaptive_bigram:.3} -> {}",
        if adaptive_bigram <= fixed_bigram + 1e-9 {
            "OK (adaptive <= fixed)"
        } else {
            "REGRESSION (adaptive > fixed)"
        }
    );
    Ok(())
}
