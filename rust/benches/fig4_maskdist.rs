//! Figure 4 — narrow (1–10%) vs wide (1–85%) prompt-rate training.
//!
//! Two arms from the same init, differing only in the prompt-length
//! distribution f(·). The validation task (as in the paper) is heavy
//! infilling: 95% masked, 5% prompt — so the arm trained on short prompts
//! should win on validation NLL (capacity concentrated on the test regime).
//!
//! Run: `cargo bench --bench fig4_maskdist`   (ASARM_ABL_STEPS to scale)

use asarm::data::{pack_chunks, split_chunks, stories};
use asarm::train::ablation::{fig4_arms, run_arms};
use asarm::train::TrainConfig;
use asarm::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !artifacts.join("train_step_b4.hlo.txt").exists() {
        eprintln!("fig4: run `make artifacts` first");
        return Ok(());
    }
    let steps: usize = std::env::var("ASARM_ABL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    let chunks = pack_chunks(&stories::corpus(556, 3000), 128);
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.05, 10);
    let base = TrainConfig {
        steps,
        lr_max: 3e-4,
        warmup_steps: steps / 10,
        decay_steps: steps,
        val_every: (steps / 6).max(1),
        val_batches: 4,
        log_every: (steps / 6).max(1),
        seed: 12,
        ..Default::default()
    };
    let results = run_arms(artifacts, 4, &base, &fig4_arms(), &train_chunks, &val_chunks)?;

    println!("\n=== Figure 4: narrow vs wide prompt-rate training ===");
    println!("validation task: infill 95% of the sequence from a 5% prompt");
    let mut table = Table::new(&["Step", "val NLL/tok (narrow 1-10%)", "val NLL/tok (wide 1-85%)"]);
    let series: Vec<Vec<(usize, f64)>> = results
        .iter()
        .map(|(_, logs)| {
            logs.iter()
                .filter_map(|l| l.val_nll_per_token.map(|v| (l.step, v)))
                .collect()
        })
        .collect();
    let rows = series[0].len().min(series[1].len());
    for r in 0..rows {
        table.row(&[
            format!("{}", series[0][r].0),
            format!("{:.4}", series[0][r].1),
            format!("{:.4}", series[1][r].1),
        ]);
    }
    table.print();
    let a = series[0].last().map(|x| x.1).unwrap_or(f64::NAN);
    let b = series[1].last().map(|x| x.1).unwrap_or(f64::NAN);
    println!(
        "final: narrow {a:.4} vs wide {b:.4}  (paper Fig. 4: narrow wins on \
         the 95%-masked validation task)"
    );
    Ok(())
}
