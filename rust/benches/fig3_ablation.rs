//! Figure 3 — recursive-binary-lattice vs any-permutation mask
//! decomposition (training ablation).
//!
//! Trains two arms from the same initialization on the same data, differing
//! only in the ordering protocol sigma ~ s(·|m): the Eq.-4 lattice (2^N
//! queries) vs unrestricted permutations (N! queries). The paper finds the
//! lattice trains better (less capacity diluted over factorization paths).
//! We log teacher-forced validation NLL per token (docs/ARCHITECTURE.md's stable
//! stand-in for the paper's generation-metric curves).
//!
//! Run: `cargo bench --bench fig3_ablation`   (ASARM_ABL_STEPS to scale)

use asarm::data::{pack_chunks, split_chunks, stories};
use asarm::train::ablation::{fig3_arms, run_arms};
use asarm::train::TrainConfig;
use asarm::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !artifacts.join("train_step_b4.hlo.txt").exists() {
        eprintln!("fig3: run `make artifacts` first");
        return Ok(());
    }
    let steps: usize = std::env::var("ASARM_ABL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    let chunks = pack_chunks(&stories::corpus(555, 3000), 128);
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.05, 9);
    let base = TrainConfig {
        steps,
        lr_max: 3e-4,
        warmup_steps: steps / 10,
        decay_steps: steps,
        val_every: (steps / 6).max(1),
        val_batches: 4,
        log_every: (steps / 6).max(1),
        seed: 11,
        ..Default::default()
    };
    let results = run_arms(artifacts, 4, &base, &fig3_arms(), &train_chunks, &val_chunks)?;

    println!("\n=== Figure 3: lattice vs any-permutation training ===");
    let mut table = Table::new(&["Step", "val NLL/tok (lattice)", "val NLL/tok (permutation)"]);
    let series: Vec<Vec<(usize, f64)>> = results
        .iter()
        .map(|(_, logs)| {
            logs.iter()
                .filter_map(|l| l.val_nll_per_token.map(|v| (l.step, v)))
                .collect()
        })
        .collect();
    let rows = series[0].len().min(series[1].len());
    for r in 0..rows {
        table.row(&[
            format!("{}", series[0][r].0),
            format!("{:.4}", series[0][r].1),
            format!("{:.4}", series[1][r].1),
        ]);
    }
    table.print();
    let last_lat = series[0].last().map(|x| x.1).unwrap_or(f64::NAN);
    let last_perm = series[1].last().map(|x| x.1).unwrap_or(f64::NAN);
    println!(
        "final: lattice {last_lat:.4} vs permutation {last_perm:.4}  \
         (paper Fig. 3: lattice consistently better)"
    );
    Ok(())
}
