//! Table 4 — ASSD vs Sequential on the OFF-THE-SHELF-style model.
//!
//! The paper's App. E.1: the model trained at XLNet-pretraining masking
//! rates (~15-20% masked, i.e. 80-85% prompts) produces more predictable
//! (lower-entropy) output distributions, so speculation accepts more and
//! ASSD's speedup grows (-49% NFE / -48% time in the paper).
//!
//! Ours: the `ckpt_stories_ots.bin` checkpoint (trained with 80-85%
//! prompts) decoded at 95% masking, Sequential vs ASSD (Self), k = 5.
//!
//! Run: `cargo bench --bench table4_ots`

use asarm::coordinator::SamplerKind;
use asarm::eval::harness::{masked_prose_workload, run_sampler};
use asarm::eval::ppl::{generative_perplexity, shannon_entropy};
use asarm::runtime::{Engine, XlaEngine};
use asarm::util::bench::Table;
use asarm::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = format!("{artifacts}/ckpt_stories_ots.bin");
    if !std::path::Path::new(&ckpt).exists() {
        eprintln!("table4: missing {ckpt}; run `make models` first");
        return Ok(());
    }
    let n_seqs: usize = std::env::var("ASARM_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ckpt)))?;
    let items = masked_prose_workload(engine.seq_len(), n_seqs, 0.95, 43);

    let mut table = Table::new(&["Sampler", "Gen PPL", "Entropy", "NFEs", "Time (s)"]);
    let mut rows: Vec<(String, f64, f64)> = vec![];
    for (label, sampler) in [
        ("Sequential", SamplerKind::Sequential),
        ("Speculative", SamplerKind::Assd),
    ] {
        let (mut ppl, mut ent, mut nfe, mut time) = (
            Summary::new(),
            Summary::new(),
            Summary::new(),
            Summary::new(),
        );
        for (i, item) in items.iter().enumerate() {
            let (out, secs) = run_sampler(&engine, item, sampler, 5, 32, 1.0, 4000 + i as u64)?;
            ppl.push(generative_perplexity(&engine, &out.tokens, 1)?);
            ent.push(shannon_entropy(&out.tokens));
            nfe.push(out.model_nfe as f64);
            time.push(secs);
        }
        rows.push((label.to_string(), nfe.mean(), time.mean()));
        table.row(&[
            label.to_string(),
            ppl.fmt_pm(),
            ent.fmt_pm(),
            nfe.fmt_pm(),
            time.fmt_pm(),
        ]);
    }
    println!("\n=== Table 4: ASSD vs Sequential, OTS-style model ===");
    table.print();
    if rows.len() == 2 {
        let dn = 100.0 * (rows[1].1 - rows[0].1) / rows[0].1;
        let dt = 100.0 * (rows[1].2 - rows[0].2) / rows[0].2;
        println!("Difference: NFE {dn:+.1}%  time {dt:+.1}%   (paper: -49.1% NFE, -48.1% time)");
    }
    Ok(())
}
