//! Perf bench (L3/L2 boundary): forward latency vs batch size, mask
//! construction cost (full rebuild vs incremental update), and literal
//! upload overhead. Feeds the perf notes in docs/ARCHITECTURE.md.
//!
//! Run: `cargo bench --bench perf_engine`

use asarm::data::masking::lattice_sigma;
use asarm::model::mask::{advance_draft_masks, draft_masks, draft_masks_into, Ordering};
use asarm::runtime::{Engine, XlaEngine};
use asarm::util::bench::{time_it, Table};
use asarm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(artifacts).join("fwd_b1.hlo.txt").exists() {
        eprintln!("perf_engine: run `make artifacts` first");
        return Ok(());
    }
    let engine = XlaEngine::load(artifacts, None)?;
    let n = engine.seq_len();
    let mut rng = Rng::new(3);

    // --- forward latency vs batch ---
    let mut table = Table::new(&[
        "op",
        "batch",
        "mean (ms)",
        "stderr (ms)",
        "per-seq (ms)",
    ]);
    for &b in &[1usize, 2, 4, 8] {
        let vis = rng.choose_sorted(n, n / 20);
        let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
        let (h1, g1) = draft_masks(&ord, ord.m);
        let mut toks = vec![0u32; b * n];
        let mut h = vec![0f32; b * n * n];
        let mut g = vec![0f32; b * n * n];
        for s in 0..b {
            for p in 0..n {
                toks[s * n + p] = rng.range(97, 123) as u32;
            }
            h[s * n * n..(s + 1) * n * n].copy_from_slice(&h1);
            g[s * n * n..(s + 1) * n * n].copy_from_slice(&g1);
        }
        let s = time_it(2, 10, || {
            engine.forward(b, &toks, &h, &g).unwrap();
        });
        table.row(&[
            "forward".into(),
            format!("{b}"),
            format!("{:.2}", s.mean() * 1e3),
            format!("{:.2}", s.stderr() * 1e3),
            format!("{:.2}", s.mean() * 1e3 / b as f64),
        ]);
    }

    // --- §Perf ablation: per-call theta literal (before) vs resident
    //     device buffer (after) ---
    {
        let vis = rng.choose_sorted(n, n / 20);
        let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
        let (h, g) = draft_masks(&ord, ord.m);
        let toks: Vec<u32> = (0..n).map(|_| rng.range(97, 123) as u32).collect();
        let before = time_it(2, 10, || {
            engine.forward_via_literals(1, &toks, &h, &g).unwrap();
        });
        let after = time_it(2, 10, || {
            engine.forward(1, &toks, &h, &g).unwrap();
        });
        table.row(&[
            "fwd b1 theta-literal (before)".into(),
            "1".into(),
            format!("{:.2}", before.mean() * 1e3),
            format!("{:.2}", before.stderr() * 1e3),
            "-".into(),
        ]);
        table.row(&[
            "fwd b1 theta-resident (after)".into(),
            "1".into(),
            format!("{:.2}", after.mean() * 1e3),
            format!("{:.2}", after.stderr() * 1e3),
            format!("{:+.1}%", 100.0 * (after.mean() - before.mean()) / before.mean()),
        ]);
    }

    // --- mask construction: full rebuild vs incremental advance ---
    let vis = rng.choose_sorted(n, n / 20);
    let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
    let m = ord.m;
    let mut h = vec![0f32; n * n];
    let mut g = vec![0f32; n * n];
    let full = time_it(5, 200, || {
        draft_masks_into(&ord, (m + 5).min(n), &mut h, &mut g);
    });
    draft_masks_into(&ord, m, &mut h, &mut g);
    let mut state = m;
    let inc = time_it(5, 200, || {
        let next = if state + 5 <= n { state + 5 } else { m };
        if next == m {
            draft_masks_into(&ord, m, &mut h, &mut g);
        } else {
            advance_draft_masks(&ord, state, next, &mut h, &mut g);
        }
        state = next;
    });
    table.row(&[
        "mask full rebuild".into(),
        "1".into(),
        format!("{:.4}", full.mean() * 1e3),
        format!("{:.4}", full.stderr() * 1e3),
        "-".into(),
    ]);
    table.row(&[
        "mask incremental(+5)".into(),
        "1".into(),
        format!("{:.4}", inc.mean() * 1e3),
        format!("{:.4}", inc.stderr() * 1e3),
        "-".into(),
    ]);

    println!("\n=== perf_engine: forward + mask-construction costs ===");
    table.print();
    println!(
        "NFE is the hardware-independent cost unit (Theorem 1); per-seq \
         forward cost at batch 4 vs 1 shows the batching win."
    );
    Ok(())
}
