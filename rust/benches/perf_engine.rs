//! Perf bench (L3/L2 boundary): the compact-vs-dense forward ABI ablation
//! and the incremental-vs-compact KV-cache ablation (same seeds, same σ
//! sweep, machine-readable output in BENCH_engine.json and
//! BENCH_incremental.json), forward latency vs batch size, mask
//! construction cost, and literal upload overhead. Feeds the perf notes
//! in docs/ARCHITECTURE.md §Compact forward ABI and §Incremental forward
//! & KV cache.
//!
//! Run: `cargo bench --bench perf_engine` (XLA artifacts), or
//! `ASARM_BENCH_MOCK=1 cargo bench --bench perf_engine` for the hermetic
//! MockEngine ablations (`make bench-smoke` / CI). The mock run FAILS
//! (non-zero exit) if the compact path regresses tokens/sec vs dense, if
//! the incremental path regresses tokens/sec vs compact (with slack — on
//! the analytic mock the two do the same host arithmetic, so the real
//! gates are the modeled-compute inequality and bit-identity), if the
//! incremental path's modeled per-iteration device compute is not
//! strictly below the compact path's from the second committed iteration
//! on, or if any path's decode outputs diverge — CI uploads both JSONs
//! and gates on this exit code.

use anyhow::{bail, Result};

use asarm::coordinator::SamplerKind;
use asarm::data::masking::lattice_sigma;
use asarm::decode::DecodeMachine;
use asarm::draft::{DraftKind, DraftOptions};
use asarm::eval::harness::{
    build_machine, masked_prose_workload, run_sampler_inc, run_sampler_with, WorkItem,
};
use asarm::model::mask::{advance_draft_masks, draft_masks, draft_masks_into, Ordering};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::{DensePath, Engine, IncSpec, XlaEngine};
use asarm::util::bench::{time_it, Table};
use asarm::util::json::Json;
use asarm::util::rng::Rng;

/// Per-iteration host<->device traffic model for one sequence (B = 1),
/// in bytes. `rows` is the gathered-row count of the compact request.
fn traffic_bytes(n: usize, v: usize, rows: usize, compact: bool) -> (u64, u64) {
    let (h2d, d2h) = if compact {
        // tokens + order (i32 each) + m + known + want indices
        ((4 * n + 4 * n + 4 + 4 + 4 * rows) as u64, (4 * rows * v) as u64)
    } else {
        // tokens + two dense [N, N] masks; full [N, V] logits back
        ((4 * n + 2 * 4 * n * n) as u64, (4 * n * v) as u64)
    };
    (h2d, d2h)
}

/// Run the σ sweep through one engine path; returns (outcomes digest,
/// total targets, total seconds, max window rows used).
fn run_sweep(
    engine: &dyn Engine,
    items: &[WorkItem],
    opts: DraftOptions,
) -> Result<(Vec<Vec<u32>>, u64, f64, usize)> {
    let mut digests = Vec::with_capacity(items.len());
    let mut targets = 0u64;
    let mut secs = 0.0;
    for (i, item) in items.iter().enumerate() {
        let (out, s) = run_sampler_with(
            engine,
            item,
            SamplerKind::Assd,
            opts,
            8,
            1.0,
            9000 + i as u64,
        )?;
        targets += item.ord.n_targets() as u64;
        secs += s;
        digests.push(out.tokens);
    }
    Ok((digests, targets, secs, opts.max_len))
}

/// The compact-vs-dense ablation on a given engine pair. Appends two
/// machine-readable result entries and returns (dense_tps, compact_tps,
/// outputs_identical).
fn ablation(
    dense_engine: &dyn Engine,
    compact_engine: &dyn Engine,
    items: &[WorkItem],
    n: usize,
    v: usize,
    check_identity: bool,
    results: &mut Vec<Json>,
) -> Result<(f64, f64, bool)> {
    let opts = DraftOptions {
        kind: DraftKind::SelfModel,
        max_len: 5,
        adaptive: false,
    };
    let (dense_out, targets, dense_s, rows) = run_sweep(dense_engine, items, opts)?;
    let (compact_out, _, compact_s, _) = run_sweep(compact_engine, items, opts)?;
    let identical = dense_out == compact_out;
    if check_identity && !identical {
        bail!("compact and dense decode outputs diverged — ABI is not a pure transport change");
    }
    let dense_tps = targets as f64 / dense_s.max(1e-12);
    let compact_tps = targets as f64 / compact_s.max(1e-12);
    for (mode, tps, secs, compact) in [
        ("dense", dense_tps, dense_s, false),
        ("compact", compact_tps, compact_s, true),
    ] {
        let (h2d, d2h) = traffic_bytes(n, v, rows, compact);
        results.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("tokens_per_sec", Json::num(tps)),
            ("wall_s", Json::num(secs)),
            ("targets", Json::num(targets as f64)),
            ("seqs", Json::num(items.len() as f64)),
            ("bytes_h2d_per_seq_iter", Json::num(h2d as f64)),
            ("bytes_d2h_per_seq_iter", Json::num(d2h as f64)),
        ]));
    }
    Ok((dense_tps, compact_tps, identical))
}

/// σ sweep shared by both engines: several mask fractions × seeds over
/// the same workload builder, so dense and compact see identical
/// (ordering, tokens, rng) streams.
fn sweep_items(n: usize) -> Vec<WorkItem> {
    let mut items = vec![];
    for (frac, seed) in [(0.5, 11u64), (0.9, 12), (0.95, 13)] {
        items.extend(masked_prose_workload(n, 2, frac, seed));
    }
    items
}

fn write_report(
    path: &str,
    engine_kind: &str,
    n: usize,
    v: usize,
    results: Vec<Json>,
    outputs_identical: bool,
    speedup: f64,
) -> Result<()> {
    let report = Json::obj(vec![
        ("engine", Json::str(engine_kind)),
        ("seq_len", Json::num(n as f64)),
        ("vocab", Json::num(v as f64)),
        ("outputs_identical", Json::Bool(outputs_identical)),
        ("speedup_compact_over_dense", Json::num(speedup)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(path, report.to_string())?;
    eprintln!("perf_engine: wrote {path}");
    Ok(())
}

/// Per-iteration host↔device traffic model for the INCREMENTAL path
/// (B = 1): `active` rows computed against a `cached + active`-column
/// attention, cache mirror re-uploaded, appended K/V rows read back.
/// The cache upload makes incremental h2d HEAVIER than compact at toy
/// scale — the incremental win is device COMPUTE, which the cells model
/// below captures; both are reported so neither story hides the other.
fn traffic_bytes_inc(
    n: usize,
    v: usize,
    active: usize,
    layers: usize,
    d: usize,
) -> (u64, u64) {
    let h2d = (4 * (2 * n + 4) + 4 * active + 2 * 4 * layers * n * d) as u64;
    let d2h = (4 * active * v + 2 * 4 * layers * active * d) as u64;
    (h2d, d2h)
}

/// Run the σ sweep through the incremental path (lane 0, reset per item);
/// returns (outcomes digest, total targets, total seconds).
fn run_sweep_inc(
    engine: &dyn Engine,
    items: &[WorkItem],
    opts: DraftOptions,
) -> Result<(Vec<Vec<u32>>, u64, f64)> {
    let mut digests = Vec::with_capacity(items.len());
    let mut targets = 0u64;
    let mut secs = 0.0;
    for (i, item) in items.iter().enumerate() {
        let (out, s) = run_sampler_inc(
            engine,
            item,
            SamplerKind::Assd,
            opts,
            8,
            1.0,
            9000 + i as u64,
            0,
        )?;
        targets += item.ord.n_targets() as u64;
        secs += s;
        digests.push(out.tokens);
    }
    Ok((digests, targets, secs))
}

/// Drive one item's decode manually through `path` (incremental when
/// true, compact otherwise) on a MockEngine, recording the modeled
/// device-compute delta of every engine call. Both paths are
/// bit-identical, so the traces are call-for-call comparable.
fn trace_modeled_cells(
    engine: &MockEngine,
    item: &WorkItem,
    opts: DraftOptions,
    seed: u64,
    incremental: bool,
) -> Result<Vec<u64>> {
    let mut machine = build_machine(engine, item, SamplerKind::Assd, opts, 8, 1.0, seed);
    let lane = 0;
    engine.reset_lane(lane);
    let mut per_call = vec![];
    while !machine.done() {
        let committed = machine.incremental();
        let before = engine.modeled_cells();
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) if incremental => engine.forward_inc(&[IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                _ => engine.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
        per_call.push(engine.modeled_cells() - before);
    }
    engine.reset_lane(lane);
    Ok(per_call)
}

/// The incremental-vs-compact ablation on the mock engine: same seeds,
/// same σ sweep as the compact-vs-dense ablation, bit-identity asserted,
/// modeled FLOP/cell + byte model reported, and the acceptance gate —
/// strictly less modeled per-iteration device compute than the compact
/// path from the second committed iteration on (the one-time prefill is
/// amortized by then).
fn mock_incremental_ablation(out_path: &str) -> Result<()> {
    let n = 128;
    let v = 258;
    // byte-model stand-ins for the mock (mirrors the DEFAULT config)
    let (layers, d) = (4usize, 128usize);
    let items = sweep_items(n);
    let opts = DraftOptions {
        kind: DraftKind::SelfModel,
        max_len: 5,
        adaptive: false,
    };
    let e_compact = MockEngine::new(7, n, v, 1.0);
    let e_inc = MockEngine::new(7, n, v, 1.0);
    let e_dense = MockEngine::new(7, n, v, 1.0);
    let (compact_out, targets, compact_s, _) = run_sweep(&e_compact, &items, opts)?;
    let (inc_out, _, inc_s) = run_sweep_inc(&e_inc, &items, opts)?;
    let (dense_out, _, _, _) = run_sweep(&DensePath(&e_dense), &items, opts)?;
    let identical = inc_out == compact_out && inc_out == dense_out;
    if !identical {
        bail!(
            "incremental decode outputs diverged from compact/dense — the KV cache is not a \
             pure compute optimization"
        );
    }
    let compact_tps = targets as f64 / compact_s.max(1e-12);
    let inc_tps = targets as f64 / inc_s.max(1e-12);
    let speedup = inc_tps / compact_tps.max(1e-12);

    // --- modeled per-iteration device compute (the acceptance gate) ---
    let e_tc = MockEngine::new(7, n, v, 1.0);
    let e_ti = MockEngine::new(7, n, v, 1.0);
    let trace_c = trace_modeled_cells(&e_tc, &items[0], opts, 9000, false)?;
    let trace_i = trace_modeled_cells(&e_ti, &items[0], opts, 9000, true)?;
    assert_eq!(trace_c.len(), trace_i.len(), "paths made different call counts");
    let mut cum_c = 0u64;
    let mut cum_i = 0u64;
    let mut crossover = None;
    for (t, (c, i)) in trace_c.iter().zip(&trace_i).enumerate() {
        cum_c += c;
        cum_i += i;
        if crossover.is_none() && cum_i < cum_c {
            crossover = Some(t + 1);
        }
        if t + 1 >= 2 && cum_i >= cum_c {
            bail!(
                "incremental cumulative modeled compute {cum_i} >= compact {cum_c} at \
                 iteration {} — the cache is not amortizing",
                t + 1
            );
        }
    }
    // mean active rows per call for the byte model
    let mean_active = (2 * opts.max_len).min(n);
    let (h2d_c, d2h_c) = traffic_bytes(n, v, opts.max_len, true);
    let (h2d_i, d2h_i) = traffic_bytes_inc(n, v, mean_active, layers, d);
    let results = vec![
        Json::obj(vec![
            ("mode", Json::str("compact")),
            ("tokens_per_sec", Json::num(compact_tps)),
            ("wall_s", Json::num(compact_s)),
            ("targets", Json::num(targets as f64)),
            ("seqs", Json::num(items.len() as f64)),
            ("modeled_cells_total", Json::num(e_compact.modeled_cells() as f64)),
            ("bytes_h2d_per_seq_iter", Json::num(h2d_c as f64)),
            ("bytes_d2h_per_seq_iter", Json::num(d2h_c as f64)),
        ]),
        Json::obj(vec![
            ("mode", Json::str("incremental")),
            ("tokens_per_sec", Json::num(inc_tps)),
            ("wall_s", Json::num(inc_s)),
            ("targets", Json::num(targets as f64)),
            ("seqs", Json::num(items.len() as f64)),
            ("modeled_cells_total", Json::num(e_inc.modeled_cells() as f64)),
            ("bytes_h2d_per_seq_iter", Json::num(h2d_i as f64)),
            ("bytes_d2h_per_seq_iter", Json::num(d2h_i as f64)),
        ]),
    ];
    let report = Json::obj(vec![
        ("engine", Json::str("mock")),
        ("seq_len", Json::num(n as f64)),
        ("vocab", Json::num(v as f64)),
        ("outputs_identical", Json::Bool(identical)),
        ("speedup_incremental_over_compact", Json::num(speedup)),
        (
            "modeled_cells_per_iter_compact",
            Json::Arr(trace_c.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        (
            "modeled_cells_per_iter_incremental",
            Json::Arr(trace_i.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        (
            "cumulative_crossover_iter",
            crossover.map_or(Json::Null, |c| Json::num(c as f64)),
        ),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(out_path, report.to_string())?;
    eprintln!("perf_engine: wrote {out_path}");

    let mut table = Table::new(&["path", "tok/s", "cells total", "h2d B/iter", "d2h B/iter"]);
    table.row(&[
        "compact".into(),
        format!("{compact_tps:.0}"),
        format!("{}", e_compact.modeled_cells()),
        format!("{h2d_c}"),
        format!("{d2h_c}"),
    ]);
    table.row(&[
        "incremental".into(),
        format!("{inc_tps:.0}"),
        format!("{}", e_inc.modeled_cells()),
        format!("{h2d_i}"),
        format!("{d2h_i}"),
    ]);
    println!("\n=== perf_engine (mock): incremental vs compact forward ===");
    table.print();
    println!(
        "speedup {speedup:.2}x wall (mock does identical host math on both paths; the device \
         win is the cells column), crossover at iteration {crossover:?}, outputs identical: \
         {identical}"
    );
    // Wall-clock gate with slack: the analytic mock computes each wanted
    // row identically on both paths, so tokens/sec should be ~equal; a
    // hard < gate would be CI noise, but a 25% regression means the lane
    // bookkeeping itself got expensive.
    if inc_tps < 0.75 * compact_tps {
        bail!("incremental path regressed: {inc_tps:.0} tok/s < 0.75x compact {compact_tps:.0}");
    }
    Ok(())
}

fn mock_ablation(out_path: &str) -> Result<()> {
    let n = 128;
    let v = 258;
    let items = sweep_items(n);
    // Same model on both sides: the paths must agree bit-for-bit.
    let e_dense = MockEngine::new(7, n, v, 1.0);
    let e_compact = MockEngine::new(7, n, v, 1.0);
    let mut results = vec![];
    let (dense_tps, compact_tps, identical) = ablation(
        &DensePath(&e_dense),
        &e_compact,
        &items,
        n,
        v,
        true,
        &mut results,
    )?;
    let speedup = compact_tps / dense_tps.max(1e-12);
    let mut table = Table::new(&["path", "tok/s", "h2d B/iter", "d2h B/iter"]);
    for r in &results {
        table.row(&[
            r.get("mode").unwrap().as_str().unwrap().to_string(),
            format!("{:.0}", r.get("tokens_per_sec").unwrap().as_f64().unwrap()),
            format!("{:.0}", r.get("bytes_h2d_per_seq_iter").unwrap().as_f64().unwrap()),
            format!("{:.0}", r.get("bytes_d2h_per_seq_iter").unwrap().as_f64().unwrap()),
        ]);
    }
    println!("\n=== perf_engine (mock): compact vs dense forward ABI ===");
    table.print();
    println!("speedup {speedup:.2}x, outputs identical: {identical}");
    write_report(out_path, "mock", n, v, results, identical, speedup)?;
    if compact_tps < dense_tps {
        bail!("compact path regressed: {compact_tps:.0} tok/s < dense {dense_tps:.0} tok/s");
    }
    Ok(())
}

fn main() -> Result<()> {
    let out_path =
        std::env::var("ASARM_BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".to_string());
    let inc_out_path = std::env::var("ASARM_BENCH_INC_OUT")
        .unwrap_or_else(|_| "BENCH_incremental.json".to_string());
    if std::env::var("ASARM_BENCH_MOCK").is_ok() {
        eprintln!("perf_engine: ASARM_BENCH_MOCK set — hermetic MockEngine ablations");
        mock_ablation(&out_path)?;
        return mock_incremental_ablation(&inc_out_path);
    }

    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(artifacts).join("fwd_b1.hlo.txt").exists() {
        eprintln!("perf_engine: run `make artifacts` first (or ASARM_BENCH_MOCK=1)");
        return Ok(());
    }
    let engine = XlaEngine::load(artifacts, None)?;
    let n = engine.seq_len();
    let v = engine.vocab();
    let mut rng = Rng::new(3);

    // --- compact-vs-dense ablation (when fwd_ord artifacts shipped) ---
    if engine.max_gather_rows() != usize::MAX {
        let items = sweep_items(n);
        let mut results = vec![];
        // XLA float reductions may be scheduled differently across the two
        // programs, so identity is not asserted here (the mock run pins
        // semantic equivalence; this measures transport).
        let (dense_tps, compact_tps, identical) = ablation(
            &DensePath(&engine),
            &engine,
            &items,
            n,
            v,
            false,
            &mut results,
        )?;
        let speedup = compact_tps / dense_tps.max(1e-12);
        println!(
            "\n=== perf_engine: compact {compact_tps:.1} tok/s vs dense {dense_tps:.1} tok/s \
             ({speedup:.2}x, outputs identical: {identical}) ==="
        );
        write_report(&out_path, "xla", n, v, results, identical, speedup)?;
    } else {
        eprintln!(
            "perf_engine: no fwd_ord_b* artifacts — regenerate with `make artifacts` for the \
             compact ablation"
        );
    }

    // --- incremental-vs-compact on the REAL artifacts (when the
    //     fwd_inc family shipped): measured tokens/sec; identity is not
    //     asserted on XLA floats (the mock run pins semantics). ---
    if engine.inc_lanes() > 0 {
        let items = sweep_items(n);
        let opts = DraftOptions {
            kind: DraftKind::SelfModel,
            max_len: 5,
            adaptive: false,
        };
        let (_, targets, compact_s, _) = run_sweep(&engine, &items, opts)?;
        let (_, _, inc_s) = run_sweep_inc(&engine, &items, opts)?;
        let compact_tps = targets as f64 / compact_s.max(1e-12);
        let inc_tps = targets as f64 / inc_s.max(1e-12);
        let speedup = inc_tps / compact_tps.max(1e-12);
        println!(
            "\n=== perf_engine: incremental {inc_tps:.1} tok/s vs compact {compact_tps:.1} \
             tok/s ({speedup:.2}x) ==="
        );
        // The incremental step currently re-uploads the packed lane
        // caches each call (no device-resident donation yet — see
        // §Incremental forward & KV cache), so on transfer-bound setups
        // the measured leg can lose to compact even though modeled
        // compute wins. Surface that loudly instead of shipping it
        // silently; the mock gates stay the CI arbiter.
        if inc_tps < compact_tps {
            eprintln!(
                "perf_engine: WARNING — measured incremental path is SLOWER than compact \
                 ({inc_tps:.1} < {compact_tps:.1} tok/s): cache-upload traffic is eating the \
                 compute win on this setup; consider serving without fwd_inc artifacts until \
                 device-resident caches land"
            );
        }
        let report = Json::obj(vec![
            ("engine", Json::str("xla")),
            ("seq_len", Json::num(n as f64)),
            ("vocab", Json::num(v as f64)),
            ("speedup_incremental_over_compact", Json::num(speedup)),
            (
                "results",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("mode", Json::str("compact")),
                        ("tokens_per_sec", Json::num(compact_tps)),
                        ("wall_s", Json::num(compact_s)),
                        ("targets", Json::num(targets as f64)),
                    ]),
                    Json::obj(vec![
                        ("mode", Json::str("incremental")),
                        ("tokens_per_sec", Json::num(inc_tps)),
                        ("wall_s", Json::num(inc_s)),
                        ("targets", Json::num(targets as f64)),
                    ]),
                ]),
            ),
        ]);
        std::fs::write(&inc_out_path, report.to_string())?;
        eprintln!("perf_engine: wrote {inc_out_path}");
    } else {
        eprintln!(
            "perf_engine: no fwd_inc_b* artifacts — regenerate with `make artifacts` for the \
             incremental ablation"
        );
    }

    // --- forward latency vs batch ---
    let mut table = Table::new(&[
        "op",
        "batch",
        "mean (ms)",
        "stderr (ms)",
        "per-seq (ms)",
    ]);
    for &b in &[1usize, 2, 4, 8] {
        let vis = rng.choose_sorted(n, n / 20);
        let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
        let (h1, g1) = draft_masks(&ord, ord.m);
        let mut toks = vec![0u32; b * n];
        let mut h = vec![0f32; b * n * n];
        let mut g = vec![0f32; b * n * n];
        for s in 0..b {
            for p in 0..n {
                toks[s * n + p] = rng.range(97, 123) as u32;
            }
            h[s * n * n..(s + 1) * n * n].copy_from_slice(&h1);
            g[s * n * n..(s + 1) * n * n].copy_from_slice(&g1);
        }
        let s = time_it(2, 10, || {
            engine.forward(b, &toks, &h, &g).unwrap();
        });
        table.row(&[
            "forward".into(),
            format!("{b}"),
            format!("{:.2}", s.mean() * 1e3),
            format!("{:.2}", s.stderr() * 1e3),
            format!("{:.2}", s.mean() * 1e3 / b as f64),
        ]);
    }

    // --- §Perf ablation: per-call theta literal (before) vs resident
    //     device buffer (after) ---
    {
        let vis = rng.choose_sorted(n, n / 20);
        let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
        let (h, g) = draft_masks(&ord, ord.m);
        let toks: Vec<u32> = (0..n).map(|_| rng.range(97, 123) as u32).collect();
        let before = time_it(2, 10, || {
            engine.forward_via_literals(1, &toks, &h, &g).unwrap();
        });
        let after = time_it(2, 10, || {
            engine.forward(1, &toks, &h, &g).unwrap();
        });
        table.row(&[
            "fwd b1 theta-literal (before)".into(),
            "1".into(),
            format!("{:.2}", before.mean() * 1e3),
            format!("{:.2}", before.stderr() * 1e3),
            "-".into(),
        ]);
        table.row(&[
            "fwd b1 theta-resident (after)".into(),
            "1".into(),
            format!("{:.2}", after.mean() * 1e3),
            format!("{:.2}", after.stderr() * 1e3),
            format!("{:+.1}%", 100.0 * (after.mean() - before.mean()) / before.mean()),
        ]);
    }

    // --- mask construction: full rebuild vs incremental advance ---
    let vis = rng.choose_sorted(n, n / 20);
    let ord = Ordering::new(lattice_sigma(&vis, n), vis.len());
    let m = ord.m;
    let mut h = vec![0f32; n * n];
    let mut g = vec![0f32; n * n];
    let full = time_it(5, 200, || {
        draft_masks_into(&ord, (m + 5).min(n), &mut h, &mut g);
    });
    draft_masks_into(&ord, m, &mut h, &mut g);
    let mut state = m;
    let inc = time_it(5, 200, || {
        let next = if state + 5 <= n { state + 5 } else { m };
        if next == m {
            draft_masks_into(&ord, m, &mut h, &mut g);
        } else {
            advance_draft_masks(&ord, state, next, &mut h, &mut g);
        }
        state = next;
    });
    table.row(&[
        "mask full rebuild".into(),
        "1".into(),
        format!("{:.4}", full.mean() * 1e3),
        format!("{:.4}", full.stderr() * 1e3),
        "-".into(),
    ]);
    table.row(&[
        "mask incremental(+5)".into(),
        "1".into(),
        format!("{:.4}", inc.mean() * 1e3),
        format!("{:.4}", inc.stderr() * 1e3),
        "-".into(),
    ]);

    println!("\n=== perf_engine: forward + mask-construction costs ===");
    table.print();
    println!(
        "NFE is the hardware-independent cost unit (Theorem 1); per-seq \
         forward cost at batch 4 vs 1 shows the batching win."
    );
    Ok(())
}
