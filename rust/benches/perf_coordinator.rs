//! Perf bench (L3): coordinator throughput under concurrent load on mock
//! engines — isolates scheduler/batcher overhead from XLA compute, and
//! ablates the three scaling axes: the continuous-batching policy
//! (max_batch, per worker), the engine-pool width (replicas), and the
//! draft subsystem (drafter kind × adaptive speculation). Feeds the perf
//! notes in docs/ARCHITECTURE.md.
//!
//! Run: `cargo bench --bench perf_coordinator`

use std::time::Instant;

use asarm::coordinator::scheduler::{spawn_pool, SchedulerConfig};
use asarm::coordinator::{DraftSpec, InfillRequest, Metrics};
use asarm::draft::{DraftKind, DraftOptions};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::{Engine, EnginePool, PoolConfig};
use asarm::util::bench::Table;

/// Drive `n_requests` through a fresh pool; returns (wall seconds, metrics).
fn run_load(
    replicas: usize,
    max_batch: usize,
    n_requests: usize,
    draft: Option<DraftOptions>,
    trace: bool,
    flight_rate: f64,
) -> (f64, Metrics) {
    let metrics = Metrics::new();
    // Same seed per replica: share-nothing copies of one model.
    let pool = EnginePool::from_fn(PoolConfig { replicas }, |_id| {
        Ok(Box::new(MockEngine::new(7, 64, 258, 1.0)) as Box<dyn Engine>)
    });
    let handle = spawn_pool(
        pool,
        SchedulerConfig {
            max_batch,
            idle_poll: std::time::Duration::from_millis(1),
            // The whole closed-loop burst is submitted before anything is
            // drained, so the bounded admission queue must hold all of it
            // (no shedding in this bench).
            queue_depth: n_requests.max(1),
            trace,
            flight_sample_rate: flight_rate,
            ..Default::default()
        },
        metrics.clone(),
    );
    // Submit all requests up front (closed-loop batch of open-loop work).
    let spec = draft.map(DraftSpec::from_options).unwrap_or_default();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            handle
                .submit(InfillRequest {
                    text: format!("{:02}____________{:02}", i % 100, i % 100),
                    seed: i as u64,
                    draft: spec,
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rh in handles {
        rh.wait().unwrap();
    }
    (t0.elapsed().as_secs_f64(), metrics)
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::var("ASARM_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);

    // --- axis 1: batching policy, single replica ---
    let mut batch_table = Table::new(&[
        "max_batch",
        "req/s",
        "p50 (ms)",
        "p99 (ms)",
        "mean occupancy",
    ]);
    for &max_batch in &[1usize, 2, 4, 8] {
        let (wall, metrics) = run_load(1, max_batch, n_requests, None, true, 0.05);
        let j = metrics.snapshot_json();
        let p50 = j.get("latency_p50_s").unwrap().as_f64().unwrap() * 1e3;
        let p99 = j.get("latency_p99_s").unwrap().as_f64().unwrap() * 1e3;
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        batch_table.row(&[
            format!("{max_batch}"),
            format!("{:.1}", n_requests as f64 / wall),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{occ:.2}"),
        ]);
    }
    println!("\n=== perf_coordinator: scheduler throughput (mock engine) ===");
    batch_table.print();
    println!("(batching amortizes per-iteration scheduling; occupancy ~max_batch when saturated)");

    // --- axis 2: engine-pool width, fixed per-worker batching ---
    let mut pool_table = Table::new(&["replicas", "req/s", "speedup", "p99 (ms)"]);
    let mut base_rps = 0.0;
    for &replicas in &[1usize, 4] {
        let (wall, metrics) = run_load(replicas, 4, n_requests, None, true, 0.05);
        let rps = n_requests as f64 / wall;
        if replicas == 1 {
            base_rps = rps;
        }
        let j = metrics.snapshot_json();
        let p99 = j.get("latency_p99_s").unwrap().as_f64().unwrap() * 1e3;
        pool_table.row(&[
            format!("{replicas}"),
            format!("{rps:.1}"),
            format!("{:.2}x", rps / base_rps),
            format!("{p99:.2}"),
        ]);
    }
    println!("\n=== perf_coordinator: engine-pool sweep (max_batch=4) ===");
    pool_table.print();
    println!("(replicas scale the forward compute across cores; shared admission queue keeps them fed)");

    // --- axis 3: drafter sweep (2 replicas, max_batch=4) ---
    let mut draft_table = Table::new(&["drafter", "req/s", "accept rate", "NFE/token"]);
    let configs = [
        ("self", DraftKind::SelfModel, false),
        ("self adaptive", DraftKind::SelfModel, true),
        ("bigram", DraftKind::Bigram, false),
        ("bigram adaptive", DraftKind::Bigram, true),
        ("lookup", DraftKind::Lookup, false),
        ("lookup adaptive", DraftKind::Lookup, true),
    ];
    for (label, kind, adaptive) in configs {
        let draft = DraftOptions {
            kind,
            max_len: 5,
            adaptive,
        };
        let (wall, metrics) = run_load(2, 4, n_requests, Some(draft), true, 0.05);
        let j = metrics.snapshot_json();
        let accept = j.get("acceptance_rate").unwrap().as_f64().unwrap();
        let nfe = j.get("model_nfe").unwrap().as_f64().unwrap();
        let toks = j.get("tokens_generated").unwrap().as_f64().unwrap();
        draft_table.row(&[
            label.to_string(),
            format!("{:.1}", n_requests as f64 / wall),
            format!("{accept:.3}"),
            format!("{:.3}", nfe / toks.max(1.0)),
        ]);
    }
    println!("\n=== perf_coordinator: drafter sweep (replicas=2, max_batch=4) ===");
    draft_table.print();
    println!(
        "(external drafters trade model NFE for aux lookups; adaptive speculation grows the \
         window while acceptance stays high)"
    );

    // --- axis 4: tracing overhead gate ---
    // Span building is a handful of Instant reads and Vec pushes per
    // iteration; it must stay invisible next to even a mock forward.
    // Best-of-3 per mode damps scheduler jitter; the bench exits
    // non-zero if tracing-on throughput drops below 0.95x off.
    let best_rps = |trace: bool| -> f64 {
        (0..3)
            .map(|_| {
                let (wall, _) = run_load(2, 4, n_requests, None, trace, 0.0);
                n_requests as f64 / wall
            })
            .fold(0.0_f64, f64::max)
    };
    let off = best_rps(false);
    let on = best_rps(true);
    let ratio = on / off;
    let mut trace_table = Table::new(&["tracing", "req/s (best of 3)", "ratio"]);
    trace_table.row(&["off".into(), format!("{off:.1}"), "1.00x".into()]);
    trace_table.row(&["on".into(), format!("{on:.1}"), format!("{ratio:.2}x")]);
    println!("\n=== perf_coordinator: tracing overhead (replicas=2, max_batch=4) ===");
    trace_table.print();
    anyhow::ensure!(
        ratio >= 0.95,
        "tracing overhead gate failed: on={on:.1} req/s vs off={off:.1} req/s ({ratio:.2}x < 0.95x)"
    );
    println!("(gate: tracing-on must hold >= 0.95x of tracing-off throughput — passed)");

    // --- axis 5: flight-recorder overhead gate ---
    // Worst case deliberately: sample rate 1.0 records EVERY request's
    // speculation anatomy (per-position outcomes plus two O(vocab)
    // entropy sweeps per wanted row). Production default is 0.05; even
    // the saturated recorder must stay within 5% of off.
    let best_flight_rps = |rate: f64| -> f64 {
        (0..3)
            .map(|_| {
                let (wall, _) = run_load(2, 4, n_requests, None, true, rate);
                n_requests as f64 / wall
            })
            .fold(0.0_f64, f64::max)
    };
    let off = best_flight_rps(0.0);
    let on = best_flight_rps(1.0);
    let ratio = on / off;
    let mut flight_table = Table::new(&["flight recorder", "req/s (best of 3)", "ratio"]);
    flight_table.row(&["off (rate 0.0)".into(), format!("{off:.1}"), "1.00x".into()]);
    flight_table.row(&["on (rate 1.0)".into(), format!("{on:.1}"), format!("{ratio:.2}x")]);
    println!("\n=== perf_coordinator: flight-recorder overhead (replicas=2, max_batch=4) ===");
    flight_table.print();
    anyhow::ensure!(
        ratio >= 0.95,
        "flight-recorder overhead gate failed: on={on:.1} req/s vs off={off:.1} req/s \
         ({ratio:.2}x < 0.95x)"
    );
    println!("(gate: flight-on (rate 1.0) must hold >= 0.95x of flight-off throughput — passed)");
    Ok(())
}
