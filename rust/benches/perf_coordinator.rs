//! Perf bench (L3): coordinator throughput under concurrent load on a mock
//! engine — isolates scheduler/batcher overhead from XLA compute, and
//! ablates the continuous-batching policy (max_batch). Feeds
//! EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench perf_coordinator`

use std::time::Instant;

use asarm::coordinator::scheduler::{spawn, SchedulerConfig};
use asarm::coordinator::{InfillRequest, Metrics};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::Engine;
use asarm::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::var("ASARM_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);

    let mut table = Table::new(&[
        "max_batch",
        "req/s",
        "p50 (ms)",
        "p99 (ms)",
        "mean occupancy",
    ]);
    for &max_batch in &[1usize, 2, 4, 8] {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let handle = spawn(
            move || Ok(Box::new(MockEngine::new(7, 64, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch,
                idle_poll: std::time::Duration::from_millis(1),
            },
            m2,
        );
        // Submit all requests up front (closed-loop batch of open-loop work).
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                handle
                    .submit(InfillRequest {
                        text: format!("{:02}____________{:02}", i % 100, i % 100),
                        seed: i as u64,
                        ..Default::default()
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let j = metrics.snapshot_json();
        let p50 = j.get("latency_p50_s").unwrap().as_f64().unwrap() * 1e3;
        let p99 = j.get("latency_p99_s").unwrap().as_f64().unwrap() * 1e3;
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        table.row(&[
            format!("{max_batch}"),
            format!("{:.1}", n_requests as f64 / wall),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{occ:.2}"),
        ]);
    }
    println!("\n=== perf_coordinator: scheduler throughput (mock engine) ===");
    table.print();
    println!("(batching amortizes per-iteration scheduling; occupancy ~max_batch when saturated)");
    Ok(())
}
