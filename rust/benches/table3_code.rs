//! Table 3 — single-line code infilling, pass@1.
//!
//! Paper setup: HumanEval single-line infilling, XLNet-Code (110M, 15B
//! code tokens) 38.59 pass@1 vs DiffuLLaMA (6.7B) 40.68.
//!
//! Ours (docs/ARCHITECTURE.md): the expression mini-language — blank one interior
//! assignment line; a completion passes iff the reassembled program prints
//! the reference value (functional judging, like HumanEval). Models: the
//! expr-trained AS-ARM with ASSD (k=15) vs the same checkpoint driven by
//! the diffusion baseline sampler, plus a random-token floor.
//!
//! Run: `cargo bench --bench table3_code`

use asarm::coordinator::SamplerKind;
use asarm::data::masking::lattice_sigma;
use asarm::eval::exprlang::make_task;
use asarm::eval::harness::{masked_span_text, run_sampler, WorkItem};
use asarm::model::mask::Ordering;
use asarm::runtime::{Engine, XlaEngine};
use asarm::tokenizer::{ByteTokenizer, MASK};
use asarm::util::bench::Table;
use asarm::util::rng::Rng;

fn task_to_item(seq_len: usize, t: &asarm::eval::exprlang::InfillTask) -> Option<WorkItem> {
    let tok = ByteTokenizer::new();
    let full = format!("{}{}{}", t.prefix, t.reference_line, t.suffix);
    if full.len() > seq_len {
        return None;
    }
    let reference = tok.encode_fixed(&full, seq_len);
    let blank_from = t.prefix.len();
    let blank_to = blank_from + t.reference_line.len();
    let mut tokens = reference.clone();
    let mut visible = vec![];
    for p in 0..seq_len {
        if p >= blank_from && p < blank_to {
            tokens[p] = MASK;
        } else {
            visible.push(p);
        }
    }
    let m = visible.len();
    Some(WorkItem {
        ord: Ordering::new(lattice_sigma(&visible, seq_len), m),
        tokens,
        reference,
    })
}

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = format!("{artifacts}/ckpt_expr.bin");
    if !std::path::Path::new(&ckpt).exists() {
        eprintln!("table3: missing {ckpt}; run `make models` first");
        return Ok(());
    }
    let n_tasks: usize = std::env::var("ASARM_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ckpt)))?;
    let n = engine.seq_len();

    let mut rng = Rng::new(55);
    let mut tasks = vec![];
    while tasks.len() < n_tasks {
        let t = make_task(&mut rng, 4);
        if let Some(item) = task_to_item(n, &t) {
            tasks.push((t, item));
        }
    }

    let mut table = Table::new(&["Model", "Pass @ 1", "NFE (mean)"]);
    // Judge calibration: the reference line must score 100.
    {
        let passes = tasks
            .iter()
            .filter(|(t, _)| t.passes(&t.reference_line))
            .count();
        table.row(&[
            "Reference line (oracle)".into(),
            format!("{:.2}", 100.0 * passes as f64 / tasks.len() as f64),
            "-".into(),
        ]);
    }
    for (label, sampler, k) in [
        ("AS-ARM expr (ASSD k=15)", Some(SamplerKind::Assd), 15),
        ("Diffusion-8 (MDLM-style)", Some(SamplerKind::Diffusion), 8),
        ("Random tokens (floor)", None, 0),
    ] {
        let mut passes = 0usize;
        let mut nfe_total = 0u64;
        for (i, (task, item)) in tasks.iter().enumerate() {
            let completion = match sampler {
                Some(s) => {
                    let (out, _) =
                        run_sampler(&engine, item, s, k, 8, 0.5, 7000 + i as u64)?;
                    nfe_total += out.model_nfe;
                    masked_span_text(item, &out.tokens)
                }
                None => {
                    let mut r = Rng::new(i as u64);
                    (0..task.reference_line.len())
                        .map(|_| (r.range(97, 123) as u8) as char)
                        .collect()
                }
            };
            if task.passes(&completion) {
                passes += 1;
            }
        }
        table.row(&[
            label.to_string(),
            format!("{:.2}", 100.0 * passes as f64 / tasks.len() as f64),
            format!("{:.1}", nfe_total as f64 / tasks.len() as f64),
        ]);
    }
    println!("\n=== Table 3: single-line infilling pass@1 ({n_tasks} tasks) ===");
    table.print();
    println!("(paper: XLNet-Code 38.59 vs DiffuLLaMA 40.68 — small AS-ARM competitive with a 50x larger diffusion model)");
    Ok(())
}
