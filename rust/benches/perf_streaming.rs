//! Perf bench (L3): the streaming request lifecycle — TTFT (time to
//! first committed token) and inter-token latency for `submit` + event
//! draining versus the blocking round-trip, swept across drafters and
//! per-worker batch sizes. Machine-readable output in
//! BENCH_streaming.json; exits non-zero if streaming ever fails to beat
//! the blocking path's total latency to the first token — the whole
//! point of the lifecycle subsystem — so CI gates on TTFT regressions.
//!
//! Hermetic by construction: the engine is the analytic mock wrapped in
//! a fixed per-forward delay ([`SlowEngine`]), so the numbers isolate
//! scheduler/lifecycle behavior from XLA compute and the TTFT < total
//! inequality is deterministic. Run: `cargo bench --bench perf_streaming`
//! (env: ASARM_BENCH_REQS requests per cell, default 8; ASARM_BENCH_OUT
//! output path).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use asarm::coordinator::lifecycle::Event;
use asarm::coordinator::scheduler::{spawn, SchedulerConfig, SchedulerHandle};
use asarm::coordinator::{DraftSpec, InfillRequest, Metrics};
use asarm::draft::{DraftKind, DraftOptions};
use asarm::runtime::mock::{MockEngine, SlowEngine};
use asarm::runtime::Engine;
use asarm::util::bench::Table;
use asarm::util::json::Json;
use asarm::util::stats::percentile;
use asarm::util::threadpool::ThreadPool;

/// Per-forward model latency: large enough that iteration counts
/// dominate thread-scheduling noise, small enough for a CI smoke run.
const FORWARD_DELAY: Duration = Duration::from_millis(3);

fn spawn_slow(max_batch: usize) -> SchedulerHandle {
    spawn(
        move || {
            Ok(Box::new(SlowEngine::new(
                MockEngine::new(7, 64, 258, 1.0),
                FORWARD_DELAY,
            )) as Box<dyn Engine>)
        },
        SchedulerConfig {
            max_batch,
            idle_poll: Duration::from_millis(1),
            queue_depth: 4096,
            ..Default::default()
        },
        Metrics::new(),
    )
}

fn request(i: u64, draft: DraftOptions) -> InfillRequest {
    InfillRequest {
        // 28 blanked bytes in a 32-byte text: plenty of iterations for
        // TTFT to be visibly earlier than completion
        text: format!("{:02}{}{:02}", i % 100, "_".repeat(28), i % 100),
        seed: i,
        draft: DraftSpec::from_options(draft),
        ..Default::default()
    }
}

struct StreamStats {
    ttft: Vec<f64>,
    itl: Vec<f64>,
    total: Vec<f64>,
    tokens: u64,
}

/// Drive `n` streaming requests concurrently; per request, timestamp the
/// first commit event (TTFT), per-token gaps (ITL), and the terminal.
fn run_streaming(h: &SchedulerHandle, n: usize, conc: usize, draft: DraftOptions) -> StreamStats {
    let results: Arc<Mutex<StreamStats>> = Arc::new(Mutex::new(StreamStats {
        ttft: vec![],
        itl: vec![],
        total: vec![],
        tokens: 0,
    }));
    let pool = ThreadPool::new(conc);
    let jobs: Vec<_> = (0..n)
        .map(|i| {
            let h = h.clone();
            let results = Arc::clone(&results);
            move || {
                let t0 = Instant::now();
                let rh = h.submit(request(i as u64, draft)).expect("submit");
                let mut first: Option<f64> = None;
                let mut gaps: Vec<f64> = vec![];
                let mut last = t0;
                let mut tokens = 0u64;
                loop {
                    match rh.next_event().expect("stream died") {
                        Event::Committed {
                            tokens: chunk,
                            positions: _,
                        } => {
                            let now = Instant::now();
                            if first.is_none() {
                                first = Some((now - t0).as_secs_f64());
                            } else {
                                gaps.push((now - last).as_secs_f64() / chunk.len() as f64);
                            }
                            tokens += chunk.len() as u64;
                            last = now;
                        }
                        Event::Done(_) => break,
                        Event::Error(e) => panic!("streaming request failed: {e}"),
                    }
                }
                let mut r = results.lock().unwrap();
                r.ttft.push(first.expect("no commit before done"));
                r.itl.extend(gaps);
                r.total.push(t0.elapsed().as_secs_f64());
                r.tokens += tokens;
            }
        })
        .collect();
    pool.scoped_run(jobs);
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("stats still shared"))
        .into_inner()
        .unwrap()
}

/// Same workload over the blocking round-trip: one latency per request.
fn run_blocking(h: &SchedulerHandle, n: usize, conc: usize, draft: DraftOptions) -> Vec<f64> {
    let results: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(vec![]));
    let pool = ThreadPool::new(conc);
    let jobs: Vec<_> = (0..n)
        .map(|i| {
            let h = h.clone();
            let results = Arc::clone(&results);
            move || {
                let t0 = Instant::now();
                h.infill(request(i as u64, draft)).expect("infill");
                results.lock().unwrap().push(t0.elapsed().as_secs_f64());
            }
        })
        .collect();
    pool.scoped_run(jobs);
    Arc::try_unwrap(results).unwrap().into_inner().unwrap()
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn main() -> Result<()> {
    let n_requests: usize = std::env::var("ASARM_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let out_path =
        std::env::var("ASARM_BENCH_OUT").unwrap_or_else(|_| "BENCH_streaming.json".to_string());
    let conc = 8;

    let drafters = [
        ("self", DraftKind::SelfModel, false),
        ("self adaptive", DraftKind::SelfModel, true),
        ("bigram", DraftKind::Bigram, false),
        ("lookup", DraftKind::Lookup, false),
    ];
    let mut table = Table::new(&[
        "drafter",
        "batch",
        "TTFT p50 (ms)",
        "ITL mean (ms/tok)",
        "stream total (ms)",
        "blocking total (ms)",
        "TTFT speedup",
    ]);
    let mut results = vec![];
    let mut regressed = false;
    for (label, kind, adaptive) in drafters {
        let draft = DraftOptions {
            kind,
            max_len: 5,
            adaptive,
        };
        for &batch in &[1usize, 4] {
            // Fresh pools per cell so queue depth and metrics are clean;
            // identical seeds on both sides.
            let h_stream = spawn_slow(batch);
            let s = run_streaming(&h_stream, n_requests, conc, draft);
            drop(h_stream);
            let h_block = spawn_slow(batch);
            let blocking = run_blocking(&h_block, n_requests, conc, draft);
            drop(h_block);

            let ttft_p50 = percentile(&s.ttft, 50.0);
            let ttft_mean = mean(&s.ttft);
            let itl_mean = mean(&s.itl);
            let stream_total = mean(&s.total);
            let blocking_total = mean(&blocking);
            let speedup = blocking_total / ttft_mean.max(1e-12);
            if ttft_mean >= blocking_total {
                regressed = true;
            }
            table.row(&[
                label.to_string(),
                format!("{batch}"),
                format!("{:.1}", ttft_p50 * 1e3),
                format!("{:.2}", itl_mean * 1e3),
                format!("{:.1}", stream_total * 1e3),
                format!("{:.1}", blocking_total * 1e3),
                format!("{speedup:.1}x"),
            ]);
            results.push(Json::obj(vec![
                ("drafter", Json::str(label)),
                ("adaptive", Json::Bool(adaptive)),
                ("max_batch", Json::num(batch as f64)),
                ("requests", Json::num(n_requests as f64)),
                ("tokens", Json::num(s.tokens as f64)),
                ("ttft_p50_s", Json::num(ttft_p50)),
                ("ttft_mean_s", Json::num(ttft_mean)),
                ("itl_mean_s", Json::num(itl_mean)),
                ("stream_total_mean_s", Json::num(stream_total)),
                ("blocking_total_mean_s", Json::num(blocking_total)),
                ("ttft_speedup", Json::num(speedup)),
            ]));
        }
    }
    println!("\n=== perf_streaming: TTFT / ITL, streaming vs blocking (mock engine) ===");
    table.print();
    println!(
        "(streaming surfaces each ASSD window's accepted prefix as it commits; blocking \
         replies only at completion — TTFT is the new first-byte latency)"
    );
    let report = Json::obj(vec![
        ("engine", Json::str("mock")),
        (
            "forward_delay_ms",
            Json::num(FORWARD_DELAY.as_secs_f64() * 1e3),
        ),
        ("requests_per_cell", Json::num(n_requests as f64)),
        ("ttft_regressed", Json::Bool(regressed)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    eprintln!("perf_streaming: wrote {out_path}");

    // Sample observability artifacts: one request's span timeline as
    // Chrome trace-event JSON (same bytes GET /trace/{id} serves) plus
    // its speculation flight record (same bytes GET /debug/flight/{id}
    // serves) — uploadable from CI; the trace loads into
    // chrome://tracing or Perfetto. Flight sampling forced to 1.0 here
    // so the artifact request is guaranteed recorded.
    let trace_path =
        std::env::var("ASARM_TRACE_OUT").unwrap_or_else(|_| "TRACE_streaming.json".to_string());
    let flight_path =
        std::env::var("ASARM_FLIGHT_OUT").unwrap_or_else(|_| "FLIGHT_streaming.json".to_string());
    let h = spawn(
        move || {
            Ok(Box::new(SlowEngine::new(
                MockEngine::new(7, 64, 258, 1.0),
                FORWARD_DELAY,
            )) as Box<dyn Engine>)
        },
        SchedulerConfig {
            max_batch: 4,
            idle_poll: Duration::from_millis(1),
            queue_depth: 4096,
            flight_sample_rate: 1.0,
            ..Default::default()
        },
        Metrics::new(),
    );
    let rh = h
        .submit(request(
            0,
            DraftOptions {
                kind: DraftKind::SelfModel,
                max_len: 5,
                adaptive: true,
            },
        ))
        .expect("submit trace sample");
    let id = rh.request_id();
    rh.wait().expect("trace sample request");
    let chrome = h
        .trace_chrome_json(id)
        .expect("tracing is on by default; the retired trace must be in the ring");
    std::fs::write(&trace_path, chrome.to_string())?;
    eprintln!("perf_streaming: wrote {trace_path} (load into chrome://tracing)");
    let flight = h
        .flight_json(id)
        .expect("flight sampling is 1.0; the record must be in the ring");
    std::fs::write(&flight_path, flight.to_string())?;
    eprintln!("perf_streaming: wrote {flight_path} (per-window speculation anatomy)");

    if regressed {
        bail!("TTFT regression: streaming first-token latency >= blocking total latency");
    }
    Ok(())
}
