//! Table 2 — story infilling (ROCStories substitute): ROUGE-1/2/L + NFE.
//!
//! Paper setup: blank the middle 1 (of 5) or middle 3 (of 5) sentences;
//! models GPT2-S / SEDD / MDLM / DiffuGPT / XLNet-OTS / XLNet-FT.
//!
//! Ours (docs/ARCHITECTURE.md): synthetic 5-sentence stories; baselines
//! re-implemented as algorithms over our AS-ARM checkpoints —
//!   AR (left->right)   GPT-style: left context only, sequential decode
//!   Diffusion-32/64    MDLM-style conditional-independence unmasking
//!   AS-ARM OTS         the 80-85%-prompt checkpoint, ASSD k=15
//!   AS-ARM FT          the wide-masking checkpoint, ASSD k=15
//!
//! Run: `cargo bench --bench table2_infill`

use asarm::coordinator::SamplerKind;
use asarm::eval::harness::{
    masked_span_text, run_ar_left_to_right, run_sampler, story_infill_workload,
};
use asarm::eval::rouge::rouge_triple;
use asarm::runtime::{Engine, XlaEngine};
use asarm::util::bench::Table;
use asarm::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ft = format!("{artifacts}/ckpt_stories_ft.bin");
    let ots = format!("{artifacts}/ckpt_stories_ots.bin");
    if !std::path::Path::new(&ft).exists() || !std::path::Path::new(&ots).exists() {
        eprintln!("table2: missing checkpoints; run `make models` first");
        return Ok(());
    }
    let n_stories: usize = std::env::var("ASARM_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);

    let ft_engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ft)))?;
    let ots_engine = XlaEngine::load(artifacts, Some(std::path::Path::new(&ots)))?;
    let n = ft_engine.seq_len();

    for (task_label, blank3) in [("Infill 1/5", false), ("Infill 3/5", true)] {
        let work = story_infill_workload(n, n_stories, blank3, 77);
        let mut table = Table::new(&["Model", "ROUGE 1/2/L", "NFE"]);

        // Row builder: decode every story, ROUGE the blanked span.
        let mut eval_row =
            |label: &str,
             f: &mut dyn FnMut(usize, &asarm::eval::harness::WorkItem)
                 -> anyhow::Result<asarm::decode::DecodeOutcome>|
             -> anyhow::Result<()> {
                let (mut r1, mut r2, mut rl, mut nfe) = (
                    Summary::new(),
                    Summary::new(),
                    Summary::new(),
                    Summary::new(),
                );
                for (i, (item, mid)) in work.iter().enumerate() {
                    let out = f(i, item)?;
                    let text = masked_span_text(item, &out.tokens);
                    let (a, b, c) = rouge_triple(&text, mid);
                    r1.push(a * 100.0);
                    r2.push(b * 100.0);
                    rl.push(c * 100.0);
                    nfe.push(out.model_nfe as f64);
                }
                table.row(&[
                    label.to_string(),
                    format!("{:.1}/{:.1}/{:.1}", r1.mean(), r2.mean(), rl.mean()),
                    format!("{:.1} ± {:.1}", nfe.mean(), nfe.std()),
                ]);
                Ok(())
            };

        eval_row("AR left-to-right (GPT-style)", &mut |i, item| {
            Ok(run_ar_left_to_right(&ft_engine, item, 0.7, 900 + i as u64)?.0)
        })?;
        eval_row("Diffusion-32 (MDLM-style)", &mut |i, item| {
            Ok(run_sampler(
                &ft_engine,
                item,
                SamplerKind::Diffusion,
                5,
                32,
                0.7,
                1900 + i as u64,
            )?
            .0)
        })?;
        eval_row("AS-ARM OTS (ASSD k=15)", &mut |i, item| {
            Ok(run_sampler(
                &ots_engine,
                item,
                SamplerKind::Assd,
                15,
                32,
                0.7,
                2900 + i as u64,
            )?
            .0)
        })?;
        eval_row("AS-ARM FT (ASSD k=15)", &mut |i, item| {
            Ok(run_sampler(
                &ft_engine,
                item,
                SamplerKind::Assd,
                15,
                32,
                0.7,
                3900 + i as u64,
            )?
            .0)
        })?;

        println!("\n=== Table 2 ({task_label}), {n_stories} stories ===");
        table.print();
    }
    println!(
        "(paper: FT surpasses OTS on 3/5 infill; AS-ARMs use far fewer NFEs than \
         fixed-step diffusion; AR trails on middle-infilling ROUGE)"
    );
    Ok(())
}
