//! Integration: real HLO artifacts through the PJRT runtime.
//!
//! Requires `make artifacts` (skipped gracefully otherwise). Verifies the
//! full L1+L2+L3 composition: the Pallas-kerneled AS-ARM runs from rust,
//! its densities satisfy the chain rule, Lemma 1 holds numerically, and
//! ASSD decodes real sequences within the Theorem-1 NFE bound.

use asarm::data::masking::lattice_sigma;
use asarm::decode::assd::AssdMachine;
use asarm::decode::sampling::log_softmax;
use asarm::decode::sequential::SequentialMachine;
use asarm::decode::{init_tokens, run_machine, DecodeMachine};
use asarm::draft::DraftKind;
use asarm::model::mask::{draft_masks, verify_masks, Ordering};
use asarm::runtime::{forward_ord_dense, Engine, ForwardSpec, XlaEngine};
use asarm::tokenizer::MASK;
use asarm::util::rng::Rng;

fn engine() -> Option<XlaEngine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("fwd_b1.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(XlaEngine::load(dir, None).expect("loading artifacts"))
}

fn random_case(e: &XlaEngine, seed: u64, m: usize) -> (Ordering, Vec<u32>, Rng) {
    let n = e.seq_len();
    let mut rng = Rng::new(seed);
    let vis = rng.choose_sorted(n, m);
    let ord = Ordering::new(lattice_sigma(&vis, n), m);
    let prompt: Vec<(usize, u32)> = vis
        .iter()
        .map(|&p| (p, rng.range(97, 123) as u32)) // ascii letters
        .collect();
    let toks = init_tokens(&ord, &prompt);
    (ord, toks, rng)
}

#[test]
fn forward_shapes_and_finiteness() {
    let Some(e) = engine() else { return };
    let n = e.seq_len();
    let v = e.vocab();
    let (ord, toks, _) = random_case(&e, 1, 6);
    let (h, g) = verify_masks(&ord);
    let logits = e.forward(1, &toks, &h, &g).unwrap();
    assert_eq!(logits.len(), n * v);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn batch4_matches_batch1() {
    let Some(e) = engine() else { return };
    let n = e.seq_len();
    let v = e.vocab();
    let (ord, toks, _) = random_case(&e, 2, 5);
    let (h, g) = verify_masks(&ord);
    let single = e.forward(1, &toks, &h, &g).unwrap();
    // same sequence replicated in 4 slots
    let mut t4 = vec![];
    let mut h4 = vec![];
    let mut g4 = vec![];
    for _ in 0..4 {
        t4.extend_from_slice(&toks);
        h4.extend_from_slice(&h);
        g4.extend_from_slice(&g);
    }
    let quad = e.forward(4, &t4, &h4, &g4).unwrap();
    for s in 0..4 {
        for i in 0..n * v {
            let a = single[i];
            let b = quad[s * n * v + i];
            assert!(
                (a - b).abs() < 1e-4,
                "slot {s} logit {i}: {a} vs {b}"
            );
        }
    }
}

/// Lemma 1 numerics on the REAL model: the draft-pass conditional at order
/// n equals the verify-pass conditional at order n.
#[test]
fn lemma1_on_real_model() {
    let Some(e) = engine() else { return };
    let v = e.vocab();
    let m = 6;
    let (ord, mut toks, mut rng) = random_case(&e, 3, m);
    // advance a few accepted tokens
    let n_known = m + 3;
    for i in m..n_known {
        toks[ord.sigma[i]] = rng.range(97, 123) as u32;
    }
    let (dh, dg) = draft_masks(&ord, n_known);
    let draft_logits = e.forward(1, &toks, &dh, &dg).unwrap();
    // verify pass needs drafts filled at n_known.. — fill arbitrary values
    let mut ver_toks = toks.clone();
    for i in n_known..ord.n() {
        ver_toks[ord.sigma[i]] = rng.range(97, 123) as u32;
    }
    let (vh, vg) = verify_masks(&ord);
    let ver_logits = e.forward(1, &ver_toks, &vh, &vg).unwrap();
    let pos = ord.sigma[n_known];
    let d = log_softmax(&draft_logits[pos * v..(pos + 1) * v], 1.0);
    let q = log_softmax(&ver_logits[pos * v..(pos + 1) * v], 1.0);
    for t in 0..v {
        assert!(
            (d[t] - q[t]).abs() < 1e-3,
            "lemma 1 violated at token {t}: draft {} vs verify {}",
            d[t],
            q[t]
        );
    }
}

/// Chain rule on the real model: one-pass joint == sum of sequential
/// conditionals (a short chain to keep runtime in check).
#[test]
fn chain_rule_on_real_model() {
    let Some(e) = engine() else { return };
    let n = e.seq_len();
    let v = e.vocab();
    let m = n - 4; // only 4 targets -> 5 forwards total
    let (ord, mut toks, mut rng) = random_case(&e, 4, m);
    // choose arbitrary target values
    let targets: Vec<(usize, u32)> = (m..n)
        .map(|i| (ord.sigma[i], rng.range(97, 123) as u32))
        .collect();

    // one-pass joint
    let mut full = toks.clone();
    for &(p, t) in &targets {
        full[p] = t;
    }
    let (vh, vg) = verify_masks(&ord);
    let logits = e.forward(1, &full, &vh, &vg).unwrap();
    let mut joint = 0.0f64;
    for &(p, t) in &targets {
        let lp = log_softmax(&logits[p * v..(p + 1) * v], 1.0);
        joint += lp[t as usize] as f64;
    }

    // sequential chain
    let mut chain = 0.0f64;
    for (idx, &(p, t)) in targets.iter().enumerate() {
        let (dh, dg) = draft_masks(&ord, m + idx);
        let lg = e.forward(1, &toks, &dh, &dg).unwrap();
        let lp = log_softmax(&lg[p * v..(p + 1) * v], 1.0);
        chain += lp[t as usize] as f64;
        toks[p] = t;
    }
    assert!(
        (joint - chain).abs() < 1e-2,
        "chain rule: joint {joint} vs chain {chain}"
    );
}

/// Theorem 1 on the real model: ASSD never exceeds one forward per token.
#[test]
fn assd_decodes_real_sequence_within_nfe_bound() {
    let Some(e) = engine() else { return };
    let n = e.seq_len();
    let m = n - 24; // 24 targets
    let (ord, toks, _) = random_case(&e, 5, m);
    let before = e.nfe();
    let mach = AssdMachine::with_kind(
        ord.clone(),
        toks,
        e.vocab(),
        5,
        1.0,
        Rng::new(99),
        DraftKind::SelfModel,
    );
    let out = run_machine(&e, Box::new(mach)).unwrap();
    let nfe = e.nfe() - before;
    assert_eq!(nfe, out.model_nfe);
    assert!(
        out.model_nfe <= 24,
        "Theorem 1 violated: {} NFE for 24 targets",
        out.model_nfe
    );
    assert!(out.tokens.iter().all(|&t| t != MASK));
}

/// Compact ABI on the REAL artifacts: the fwd_ord path (on-device mask
/// construction + row gather) must numerically match the dense path
/// (host-built masks + full logits + host-side gather) on every requested
/// row. Skipped when the artifact set predates the compact family.
#[test]
fn compact_forward_matches_dense_on_real_artifacts() {
    let Some(e) = engine() else { return };
    if e.max_gather_rows() == usize::MAX {
        eprintln!("skipping: no fwd_ord_b* artifacts (regenerate with `make artifacts`)");
        return;
    }
    let v = e.vocab();
    let m = 6;
    let (ord, toks, mut rng) = random_case(&e, 8, m);
    for known in [m, m + 3, ord.n()] {
        let n_want = e.max_gather_rows().min(5);
        let want: Vec<usize> = (0..n_want).map(|_| rng.below(ord.n())).collect();
        let spec = ForwardSpec {
            tokens: &toks,
            ord: &ord,
            known,
            want: &want,
        };
        let compact = e.forward_ord(std::slice::from_ref(&spec)).unwrap();
        let dense = forward_ord_dense(&e, std::slice::from_ref(&spec)).unwrap();
        assert_eq!(compact[0].len(), n_want * v);
        for (i, (a, b)) in compact[0].iter().zip(&dense[0]).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "known={known} row-elem {i}: compact {a} vs dense {b}"
            );
        }
    }
}

/// Incremental path on the REAL artifacts: a multi-iteration commit
/// schedule through fwd_inc (prefill + per-iteration appends against the
/// persistent lane cache) must numerically match the compact path at
/// every step. Skipped when the artifact set predates the incremental
/// family.
#[test]
fn incremental_forward_matches_compact_on_real_artifacts() {
    use asarm::runtime::IncSpec;
    let Some(e) = engine() else { return };
    if e.inc_lanes() == 0 {
        eprintln!("skipping: no fwd_inc_b* artifacts (regenerate with `make artifacts`)");
        return;
    }
    let n = e.seq_len();
    let v = e.vocab();
    let m = n - 12; // 12 targets
    let (ord, mut toks, mut rng) = random_case(&e, 11, m);
    e.reset_lane(0);
    let mut c = m;
    let w = 3;
    while c < n {
        let t = (c + w).min(n);
        let window: Vec<usize> = (c..t).map(|i| ord.sigma[i]).collect();
        for (known, fill) in [(c, false), (ord.n(), true)] {
            if fill {
                for &pos in &window {
                    toks[pos] = rng.range(97, 123) as u32;
                }
            }
            let spec = ForwardSpec {
                tokens: &toks,
                ord: &ord,
                known,
                want: &window,
            };
            let inc = e
                .forward_inc(&[IncSpec {
                    spec,
                    committed: c,
                    lane: 0,
                }])
                .unwrap();
            let compact = e.forward_ord(std::slice::from_ref(&spec)).unwrap();
            assert_eq!(inc[0].len(), window.len() * v);
            for (i, (a, b)) in inc[0].iter().zip(&compact[0]).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3,
                    "c={c} known={known} row-elem {i}: inc {a} vs compact {b}"
                );
            }
        }
        // commit the window (the verify loop above already filled tokens)
        c = t;
    }
    e.reset_lane(0);
}

#[test]
fn sequential_decodes_real_sequence() {
    let Some(e) = engine() else { return };
    let n = e.seq_len();
    let m = n - 8;
    let (ord, toks, _) = random_case(&e, 6, m);
    let mach = SequentialMachine::new(ord, toks, e.vocab(), 1.0, Rng::new(7));
    let out = run_machine(&e, Box::new(mach)).unwrap();
    assert_eq!(out.model_nfe, 8);
    assert!(out.tokens.iter().all(|&t| t != MASK));
}
