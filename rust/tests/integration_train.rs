//! Integration: the rust training loop through the AOT train_step artifact
//! (requires `make artifacts`; skipped otherwise). Verifies the loss falls,
//! checkpoints round-trip, and trained weights flow into the serving
//! engine.

use asarm::data::{pack_chunks, split_chunks, stories};
use asarm::runtime::engine::TrainRunner;
use asarm::runtime::XlaEngine;
use asarm::train::{train, TrainConfig};

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .join("train_step_b4.hlo.txt")
        .exists()
}

#[test]
fn train_step_reduces_loss_and_checkpoints() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let mut runner = TrainRunner::load(artifacts, 4).unwrap();
    let chunks = pack_chunks(&stories::corpus(99, 600), runner.meta.seq_len);
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.1, 1);

    let ckpt = std::env::temp_dir().join("asarm_itest_ckpt.bin");
    let cfg = TrainConfig {
        steps: 25,
        lr_max: 5e-4,
        warmup_steps: 3,
        decay_steps: 25,
        log_every: 5,
        val_every: 0,
        checkpoint: Some(ckpt.clone()),
        ..Default::default()
    };
    let logs = train(&mut runner, &train_chunks, &val_chunks, &cfg, None).unwrap();
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last} did not fall");
    assert!(last.is_finite());

    // Checkpoint round-trips and loads into the serving engine.
    let theta = asarm::model::load_params(&ckpt, runner.meta.n_params).unwrap();
    assert_eq!(theta.len(), runner.meta.n_params);
    assert_eq!(theta, runner.theta);
    let engine = XlaEngine::load(artifacts, Some(&ckpt)).unwrap();
    assert_eq!(engine.params(), &theta[..]);
}

#[test]
fn validation_nll_drops_with_training() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let mut runner = TrainRunner::load(artifacts, 4).unwrap();
    let chunks = pack_chunks(&stories::corpus(98, 600), runner.meta.seq_len);
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.1, 2);
    let mut val_engine = XlaEngine::load(artifacts, None).unwrap();

    let cfg = TrainConfig {
        steps: 21,
        lr_max: 5e-4,
        warmup_steps: 3,
        decay_steps: 21,
        log_every: 10,
        val_every: 20,
        val_batches: 3,
        checkpoint: None,
        ..Default::default()
    };
    let logs = train(
        &mut runner,
        &train_chunks,
        &val_chunks,
        &cfg,
        Some(&mut val_engine),
    )
    .unwrap();
    let vals: Vec<f64> = logs.iter().filter_map(|l| l.val_nll_per_token).collect();
    assert!(vals.len() >= 2, "need at least two validation points");
    assert!(
        vals.last().unwrap() < vals.first().unwrap(),
        "val NLL did not improve: {vals:?}"
    );
}
