//! Chaos soak: the fault-tolerance headline, end to end. A seeded
//! [`ChaosConfig`] schedule injects transient faults, lane
//! invalidations, and latency spikes into every decode mode (ASSD over
//! all drafters, sequential, diffusion); the suite asserts that every
//! request completes BIT-IDENTICAL to its fault-free twin, that
//! recovery never perturbs NFE accounting (Theorem 2's
//! `model_nfe <= tokens_committed` bound survives every retry), and
//! that a fatally dead replica is re-provisioned by the supervisor with
//! the in-flight request MIGRATING (checkpoint → restore) onto the
//! fresh incarnation instead of failing — including a kill-mid-decode
//! leg where the engine dies with committed tokens in flight and the
//! migrated output still matches the fault-free twin bit-for-bit.
//!
//! The schedule seed is pinned by `make chaos` via `ASARM_CHAOS_SEED`
//! (default 20260808) so CI failures reproduce locally with
//! `ASARM_CHAOS_SEED=<seed> cargo test --release --test chaos_soak`.
//! On mismatch the suite still writes `TRACE_chaos.json` (a Chrome
//! trace of the last chaos-run request) BEFORE asserting, so the CI
//! artifact upload has something to grab.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use asarm::coordinator::http::{http_get, http_post, HttpServer};
use asarm::coordinator::scheduler::{spawn, spawn_pool};
use asarm::coordinator::{
    DraftSpec, InfillRequest, InfillResponse, Metrics, SamplerKind, SchedulerConfig,
    SchedulerHandle,
};
use asarm::draft::{DraftKind, DraftOptions};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::{
    ChaosConfig, Engine, EngineError, EnginePool, EngineResult, ForwardSpec, IncSpec, KvStats,
    PoolConfig,
};
use asarm::util::json::Json;

/// Fault rate for the soak. The acceptance bar is >= 0.1; 0.2 trips
/// roughly one fault per request on the 10-char infill workload while
/// staying far from the retry budget.
const CHAOS_RATE: f64 = 0.2;

fn chaos_seed() -> u64 {
    std::env::var("ASARM_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20260808)
}

/// One single-replica scheduler over a MockEngine, with chaos injection
/// at `rate` (0.0 = the fault-free twin). The generous retry budget and
/// effectively-disabled quarantine keep the incarnation alive for the
/// whole soak — supervision is exercised separately, deterministically,
/// by [`replica_death_supervised_restart_over_http`].
fn chaos_handle(rate: f64, seed: u64) -> (SchedulerHandle, Metrics) {
    let metrics = Metrics::new();
    let handle = spawn(
        move || Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch: 3,
            idle_poll: Duration::from_millis(2),
            chaos: ChaosConfig {
                seed,
                rate,
                spike: Duration::from_micros(20),
            },
            retry_budget: 64,
            health: asarm::runtime::HealthPolicy {
                degrade_after: 3,
                quarantine_after: 1_000_000,
            },
            // Record every request's speculation flight: the soak doubles
            // as proof that the recorder rides through fault recovery,
            // and FLIGHT_chaos.json below needs a guaranteed sample.
            flight_sample_rate: 1.0,
            ..Default::default()
        },
        metrics.clone(),
    );
    (handle, metrics)
}

fn run(h: &SchedulerHandle, sampler: SamplerKind, draft: DraftKind, seed: u64) -> InfillResponse {
    h.submit(InfillRequest {
        text: "ab______cd".to_string(),
        sampler,
        draft: DraftSpec::from_options(DraftOptions {
            kind: draft,
            max_len: 4,
            adaptive: true,
        }),
        seed,
        ..Default::default()
    })
    .expect("submit")
    .wait()
    .expect("request failed instead of recovering")
}

/// Every decode mode, under injected faults, completes bit-identical to
/// the fault-free run with NFE accounting untouched — the tentpole's
/// headline property. Aggregate counters then prove faults were
/// actually injected and recovered (not silently skipped).
#[test]
fn chaos_soak_bit_identical_across_all_modes() {
    let seed = chaos_seed();
    let (clean, _clean_metrics) = chaos_handle(0.0, seed);
    let (chaos, metrics) = chaos_handle(CHAOS_RATE, seed);

    // (sampler, drafter) matrix: ASSD over every drafter, the legacy
    // ngram alias, and both non-speculative baselines.
    let mut modes: Vec<(SamplerKind, DraftKind)> = DraftKind::ALL
        .iter()
        .map(|&d| (SamplerKind::Assd, d))
        .collect();
    modes.push((SamplerKind::AssdNgram, DraftKind::Bigram));
    modes.push((SamplerKind::Sequential, DraftKind::SelfModel));
    modes.push((SamplerKind::Diffusion, DraftKind::SelfModel));

    let mut mismatches: Vec<String> = Vec::new();
    let mut last_chaos_id = 0u64;
    for &(sampler, draft) in &modes {
        for seed_r in [1u64, 2, 3] {
            let want = run(&clean, sampler, draft, seed_r);
            let got = run(&chaos, sampler, draft, seed_r);
            last_chaos_id = got.request_id;
            if got.text != want.text {
                mismatches.push(format!(
                    "{}/{} seed {seed_r}: text {:?} != fault-free {:?}",
                    sampler.name(),
                    draft.name(),
                    got.text,
                    want.text
                ));
            }
            if got.model_nfe != want.model_nfe {
                mismatches.push(format!(
                    "{}/{} seed {seed_r}: model_nfe {} != fault-free {} (retries leaked in)",
                    sampler.name(),
                    draft.name(),
                    got.model_nfe,
                    want.model_nfe
                ));
            }
            // Theorem 2 per request: one verification launch per
            // committed token at worst. Diffusion is exempt (its NFE is
            // the step count, not bounded by tokens).
            if sampler != SamplerKind::Diffusion && got.model_nfe > got.n_generated as u64 {
                mismatches.push(format!(
                    "{}/{} seed {seed_r}: model_nfe {} > tokens {} (Theorem 2 violated)",
                    sampler.name(),
                    draft.name(),
                    got.model_nfe,
                    got.n_generated
                ));
            }
        }
    }

    // Dump the chaos-run trace and flight record BEFORE asserting so a
    // red CI run still uploads artifacts to debug from.
    if let Some(trace) = chaos.trace_chrome_json(last_chaos_id) {
        let _ = std::fs::write("TRACE_chaos.json", trace.to_string());
    }
    if let Some(flight) = chaos.flight_json(last_chaos_id) {
        let _ = std::fs::write("FLIGHT_chaos.json", flight.to_string());
    }

    assert!(
        mismatches.is_empty(),
        "chaos run diverged from fault-free run (seed {seed}):\n{}",
        mismatches.join("\n")
    );

    // The soak only proves something if faults actually fired.
    let (transient, lane_corrupt, fatal) = metrics.engine_errors();
    assert!(
        transient + lane_corrupt > 0,
        "chaos rate {CHAOS_RATE} injected no faults (seed {seed}) — soak proved nothing"
    );
    assert_eq!(fatal, 0, "chaos schedule must not inject fatal faults");
    assert!(metrics.forward_retries() > 0, "faults recovered without retries?");
    assert_eq!(
        metrics.requests_failed(),
        0,
        "requests failed under chaos despite the retry budget"
    );
    assert_eq!(
        metrics.replica_restarts(),
        0,
        "soak incarnation should survive (quarantine disabled)"
    );
    assert_eq!(metrics.theorem2_violations(), 0);
}

/// A replica whose engine dies fatally is re-provisioned by the
/// supervisor — and the in-flight request RIDES THROUGH: its slot is
/// checkpointed off the dead incarnation (the failed forward never
/// absorbed), re-queued, and resumed to completion on the fresh engine.
/// Replica death costs latency, never requests — all observed from
/// outside, over HTTP.
struct DeadOnArrival;

impl Engine for DeadOnArrival {
    fn seq_len(&self) -> usize {
        32
    }
    fn vocab(&self) -> usize {
        258
    }
    fn forward(
        &self,
        _batch: usize,
        _tokens: &[u32],
        _mask_h: &[f32],
        _mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        Err(EngineError::fatal("device lost (chaos soak)"))
    }
    fn nfe(&self) -> u64 {
        0
    }
}

#[test]
fn replica_death_supervised_restart_over_http() {
    let metrics = Metrics::new();
    let built = Arc::new(AtomicUsize::new(0));
    let b2 = Arc::clone(&built);
    // Incarnation 0 is fatally broken; every re-provision yields a
    // healthy engine.
    let pool = EnginePool::from_fn(PoolConfig { replicas: 1 }, move |_id| {
        let incarnation = b2.fetch_add(1, Ordering::SeqCst);
        if incarnation == 0 {
            Ok(Box::new(DeadOnArrival) as Box<dyn Engine>)
        } else {
            Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>)
        }
    });
    let handle = spawn_pool(
        pool,
        SchedulerConfig {
            max_batch: 2,
            idle_poll: Duration::from_millis(2),
            ..Default::default()
        },
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics.clone(), 2).unwrap();
    let addr = server.serve_background();

    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200, "{body}");

    // First request lands on the dead incarnation. It does NOT fail: the
    // slot is checkpointed, waits out the restart backoff in the resume
    // queue, and the fresh incarnation serves it to completion.
    let body = r#"{"text":"ab____cd","sampler":"assd","seed":7}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "migrated request must succeed: {resp}");
    let j = Json::parse(&resp).unwrap();
    let migrated = j.get("text").unwrap().as_str().unwrap().to_string();
    assert!(!migrated.contains('_'), "unfilled masks: {migrated}");

    // Migration is invisible in the output: the dead incarnation never
    // absorbed a forward, so the migrated text matches a pool that was
    // healthy from the start.
    let healthy = spawn(
        move || Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch: 2,
            idle_poll: Duration::from_millis(2),
            ..Default::default()
        },
        Metrics::new(),
    );
    let want = healthy
        .infill(InfillRequest {
            text: "ab____cd".into(),
            seed: 7,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(migrated, want.text, "migration must be bit-invisible");

    assert_eq!(built.load(Ordering::SeqCst), 2, "exactly one re-provision");
    assert_eq!(metrics.replica_restarts(), 1);
    assert_eq!(metrics.migrations(), 1, "slot must migrate, not fail");
    assert_eq!(metrics.requests_failed(), 0, "migration must not fail requests");

    // Subsequent admissions are served directly by the fresh incarnation.
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "after restart: {resp}");
    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200, "pool must report serving after recovery: {body}");
}

/// The kill-mid-decode engine: serves `healthy_calls` forwards, then
/// dies fatally on every later call — simulating a device lost with
/// committed tokens in flight. The fatal call is rejected BEFORE
/// reaching the inner engine, so the dead incarnation never absorbs it
/// and the migrated run's NFE accounting can match the fault-free twin
/// exactly.
struct DiesMidDecode {
    inner: MockEngine,
    calls: AtomicU64,
    healthy_calls: u64,
}

impl DiesMidDecode {
    fn new(healthy_calls: u64) -> DiesMidDecode {
        DiesMidDecode {
            inner: MockEngine::new(5, 32, 258, 1.0),
            calls: AtomicU64::new(0),
            healthy_calls,
        }
    }

    fn trip(&self) -> EngineResult<()> {
        if self.calls.fetch_add(1, Ordering::SeqCst) >= self.healthy_calls {
            return Err(EngineError::fatal("device lost mid-decode (chaos soak)"));
        }
        Ok(())
    }
}

impl Engine for DiesMidDecode {
    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        self.trip()?;
        self.inner.forward(batch, tokens, mask_h, mask_g)
    }

    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        self.trip()?;
        self.inner.forward_ord(specs)
    }

    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        self.trip()?;
        self.inner.forward_inc(specs)
    }

    fn inc_lanes(&self) -> usize {
        self.inner.inc_lanes()
    }

    fn reset_lane(&self, lane: usize) {
        self.inner.reset_lane(lane)
    }

    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }

    fn max_gather_rows(&self) -> usize {
        self.inner.max_gather_rows()
    }

    fn nfe(&self) -> u64 {
        self.inner.nfe()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }
}

/// Kill -9 mid-decode, across decode modes: the engine dies fatally
/// AFTER absorbing two forwards (sequential and diffusion have committed
/// tokens by then; ASSD may be mid-draft — both are legal checkpoint
/// points). The request migrates onto the re-provisioned incarnation and
/// completes BIT-IDENTICAL to the fault-free twin, with identical NFE
/// accounting and zero failed requests — dying replicas cost latency,
/// never requests.
#[test]
fn kill_mid_decode_migrates_and_stays_bit_identical() {
    let modes: [(SamplerKind, DraftKind); 3] = [
        (SamplerKind::Assd, DraftKind::SelfModel),
        (SamplerKind::Sequential, DraftKind::SelfModel),
        (SamplerKind::Diffusion, DraftKind::SelfModel),
    ];
    for (sampler, draft) in modes {
        let metrics = Metrics::new();
        let built = Arc::new(AtomicUsize::new(0));
        let b2 = Arc::clone(&built);
        // Incarnation 0 dies after two forwards; re-provisions are healthy.
        let pool = EnginePool::from_fn(PoolConfig { replicas: 1 }, move |_id| {
            if b2.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(DiesMidDecode::new(2)) as Box<dyn Engine>)
            } else {
                Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>)
            }
        });
        let handle = spawn_pool(
            pool,
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(2),
                ..Default::default()
            },
            metrics.clone(),
        );
        let (clean, _clean_metrics) = chaos_handle(0.0, 1);

        let got = run(&handle, sampler, draft, 5);
        let want = run(&clean, sampler, draft, 5);
        let tag = format!("{}/{}", sampler.name(), draft.name());
        assert!(!got.text.contains('_'), "{tag}: unfilled masks: {}", got.text);
        assert_eq!(got.text, want.text, "{tag}: migrated text diverged");
        assert_eq!(
            got.model_nfe, want.model_nfe,
            "{tag}: migration leaked NFEs (the dead incarnation's failed call must not count)"
        );

        assert_eq!(built.load(Ordering::SeqCst), 2, "{tag}: exactly one re-provision");
        assert_eq!(metrics.replica_restarts(), 1, "{tag}");
        assert_eq!(metrics.migrations(), 1, "{tag}: slot must migrate, not fail");
        assert_eq!(metrics.requests_failed(), 0, "{tag}: migration must not fail requests");
        assert_eq!(metrics.theorem2_violations(), 0, "{tag}");
        assert!(handle.healthy(), "{tag}: pool must keep serving after migration");
    }
}
