//! Integration: decode-state checkpointing under the three seams that
//! consume it — KV-pressure preemption, drain-free restarts, and the
//! deadline clock while parked. All hermetic over mock engines.
//!
//! The correctness currency throughout is BIT-IDENTITY: a request that
//! was checkpointed, parked, and resumed must produce exactly the token
//! stream (no duplicate, no reorder, no divergence) of an uninterrupted
//! run with the same seed. Migration across engine death is covered by
//! `chaos_soak.rs`; the snapshot-layer property tests live in
//! `decode::snapshot`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use asarm::coordinator::http::{http_get, http_post, http_post_stream, HttpServer};
use asarm::coordinator::lifecycle::{Event, RequestHandle};
use asarm::coordinator::scheduler::{spawn, SchedulerConfig, SchedulerHandle, SubmitError};
use asarm::coordinator::{DraftSpec, InfillRequest, InfillResponse, Metrics, SamplerKind};
use asarm::draft::{DraftKind, DraftOptions};
use asarm::runtime::mock::{MockEngine, SlowEngine};
use asarm::runtime::{Engine, EngineError, EngineResult, ForwardSpec, IncSpec, KvStats};
use asarm::util::json::Json;

/// A [`MockEngine`] that reports KV-pool exhaustion exactly once, on the
/// first batched forward serving two or more sequences — i.e. precisely
/// when the scheduler has a batch-mate to preempt. Every other call
/// delegates unchanged, so outputs stay bit-identical to the plain mock.
/// The small per-call delay widens the admission window so two
/// back-to-back submissions reliably overlap.
struct PressureEngine {
    inner: MockEngine,
    delay: Duration,
    fired: AtomicBool,
}

impl PressureEngine {
    fn new(inner: MockEngine) -> PressureEngine {
        PressureEngine {
            inner,
            delay: Duration::from_millis(2),
            fired: AtomicBool::new(false),
        }
    }

    fn inject(&self, batch: usize) -> EngineResult<()> {
        if batch >= 2 && !self.fired.swap(true, Ordering::Relaxed) {
            return Err(EngineError::kv_pressure(
                "injected pool exhaustion (test)",
            ));
        }
        Ok(())
    }
}

impl Engine for PressureEngine {
    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        self.inner.forward(batch, tokens, mask_h, mask_g)
    }

    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inject(specs.len())?;
        self.inner.forward_ord(specs)
    }

    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inject(specs.len())?;
        self.inner.forward_inc(specs)
    }

    fn inc_lanes(&self) -> usize {
        self.inner.inc_lanes()
    }

    fn reset_lane(&self, lane: usize) {
        self.inner.reset_lane(lane)
    }

    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }

    fn max_gather_rows(&self) -> usize {
        self.inner.max_gather_rows()
    }

    fn nfe(&self) -> u64 {
        self.inner.nfe()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }
}

fn mock() -> MockEngine {
    MockEngine::new(5, 32, 258, 1.0)
}

fn pool<E, F>(factory: F, max_batch: usize) -> (SchedulerHandle, Metrics)
where
    E: Engine + Send + 'static,
    F: FnOnce() -> E + Send + 'static,
{
    let metrics = Metrics::new();
    let handle = spawn(
        move || Ok(Box::new(factory()) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch,
            idle_poll: Duration::from_millis(1),
            ..Default::default()
        },
        metrics.clone(),
    );
    (handle, metrics)
}

/// Drain one request's event stream into its flattened (position, token)
/// commit sequence plus the final response.
fn drain(rh: RequestHandle) -> (Vec<(usize, u32)>, InfillResponse) {
    let mut commits = Vec::new();
    loop {
        match rh.next_event() {
            Some(Event::Committed { positions, tokens }) => {
                commits.extend(positions.into_iter().zip(tokens));
            }
            Some(Event::Done(resp)) => return (commits, resp),
            Some(Event::Error(e)) => panic!("request failed: {e}"),
            None => panic!("scheduler dropped request"),
        }
    }
}

fn assert_each_target_once(commits: &[(usize, u32)], tag: &str) {
    let mut seen = std::collections::HashSet::new();
    for &(pos, _) in commits {
        assert!(seen.insert(pos), "{tag}: position {pos} committed twice");
    }
    assert_eq!(commits.len(), 8, "{tag}: wrong commit count");
}

/// ACCEPTANCE (satellite c): preemption under KV pressure is invisible to
/// the client — for all three decode machines and every drafter, the
/// preempted-and-resumed run streams each target exactly once, in the
/// same (position, token) order, to the same final text as an
/// uninterrupted run with the same seed.
#[test]
fn kv_pressure_preemption_streams_bit_identically_for_all_machines() {
    let configs: &[(&str, SamplerKind, DraftSpec)] = &[
        (
            "assd/self+adaptive",
            SamplerKind::Assd,
            DraftSpec::from_options(DraftOptions {
                kind: DraftKind::SelfModel,
                max_len: 4,
                adaptive: true,
            }),
        ),
        (
            "assd/bigram",
            SamplerKind::Assd,
            DraftSpec::from_options(DraftOptions {
                kind: DraftKind::Bigram,
                max_len: 4,
                adaptive: false,
            }),
        ),
        (
            "assd/lookup",
            SamplerKind::Assd,
            DraftSpec::from_options(DraftOptions {
                kind: DraftKind::PromptLookup,
                max_len: 4,
                adaptive: false,
            }),
        ),
        ("sequential", SamplerKind::Sequential, DraftSpec::default()),
        ("diffusion", SamplerKind::Diffusion, DraftSpec::default()),
    ];
    for (tag, sampler, draft) in configs {
        let req = |seed: u64| InfillRequest {
            text: "ab________cd".into(),
            sampler: *sampler,
            draft: draft.clone(),
            seed,
            ..Default::default()
        };
        // Uninterrupted twin: same engine seed, no injected pressure.
        let (clean, _) = pool(mock, 2);
        let c1 = clean.submit(req(11)).unwrap();
        let c2 = clean.submit(req(12)).unwrap();
        let (clean1, clean_resp1) = drain(c1);
        let (clean2, clean_resp2) = drain(c2);

        let (pressured, metrics) = pool(|| PressureEngine::new(mock()), 2);
        let p1 = pressured.submit(req(11)).unwrap();
        let p2 = pressured.submit(req(12)).unwrap();
        let (got1, resp1) = drain(p1);
        let (got2, resp2) = drain(p2);

        assert_eq!(
            metrics.preemptions(),
            1,
            "{tag}: pressure with a batch-mate must preempt exactly once"
        );
        assert_eq!(metrics.requests_failed(), 0, "{tag}");
        assert_eq!(metrics.requests(), 2, "{tag}: both requests completed");
        assert_each_target_once(&got1, tag);
        assert_each_target_once(&got2, tag);
        assert_eq!(got1, clean1, "{tag}: seed 11 commit stream diverged");
        assert_eq!(got2, clean2, "{tag}: seed 12 commit stream diverged");
        assert_eq!(resp1.text, clean_resp1.text, "{tag}");
        assert_eq!(resp2.text, clean_resp2.text, "{tag}");
        assert!(!resp1.text.contains('_'), "{tag}: {}", resp1.text);
    }
}

/// Preemption must NOT spend the request's retry budget or count as an
/// engine-health event: with retry_budget 0, a kv-pressure failure that
/// has a preemptable batch-mate still completes every request.
#[test]
fn preemption_spends_no_retry_budget() {
    let metrics = Metrics::new();
    let handle = spawn(
        || Ok(Box::new(PressureEngine::new(mock())) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch: 2,
            idle_poll: Duration::from_millis(1),
            retry_budget: 0,
            ..Default::default()
        },
        metrics.clone(),
    );
    let req = |seed: u64| InfillRequest {
        text: "ab________cd".into(),
        seed,
        ..Default::default()
    };
    let r1 = handle.submit(req(1)).unwrap();
    let r2 = handle.submit(req(2)).unwrap();
    let (_, resp1) = drain(r1);
    let (_, resp2) = drain(r2);
    assert!(!resp1.text.contains('_'));
    assert!(!resp2.text.contains('_'));
    assert_eq!(metrics.preemptions(), 1);
    assert_eq!(metrics.requests_failed(), 0);
}

/// ACCEPTANCE (drain): POST-/drain semantics at the scheduler level —
/// active slots checkpoint and park, admissions are refused while the
/// flag is up, and lifting it resumes the parked slot to a final text
/// bit-identical to an undrained run. The client's handle stays open
/// across the park: no event is lost, none is re-emitted.
#[test]
fn drain_parks_then_resume_completes_bit_identically() {
    let req = InfillRequest {
        text: "ab________cd".into(),
        sampler: SamplerKind::Sequential,
        seed: 7,
        ..Default::default()
    };
    // Undrained twin for the reference text.
    let (clean, _) = pool(mock, 1);
    let expected = clean.infill(req.clone()).unwrap().text;

    let (handle, metrics) = pool(|| SlowEngine::new(mock(), Duration::from_millis(10)), 1);
    let rh = handle.submit(req.clone()).unwrap();
    // First commit proves the decode is mid-flight before we drain.
    let first = rh.next_event();
    let mut commits: Vec<(usize, u32)> = Vec::new();
    match first {
        Some(Event::Committed { positions, tokens }) => {
            commits.extend(positions.into_iter().zip(tokens))
        }
        other => panic!("expected a commit first, got {other:?}"),
    }
    handle.set_draining(true);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while handle.parked() == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "drain never parked the active slot"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(handle.draining());
    assert!(matches!(
        handle.submit(req.clone()),
        Err(SubmitError::Draining)
    ));
    let j = handle.drain_json();
    assert_eq!(j.get("draining").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("parked").unwrap().as_f64(), Some(1.0));
    assert!(j.get("drains").unwrap().as_f64().unwrap() >= 1.0);

    handle.set_draining(false);
    let (rest, resp) = drain(rh);
    commits.extend(rest);
    assert_each_target_once(&commits, "drain/resume");
    assert_eq!(resp.text, expected, "resume diverged from undrained run");
    assert!(metrics.drains() >= 1);
    assert_eq!(metrics.requests_failed(), 0);
    assert_eq!(handle.parked(), 0);
    // The drain lifted: new admissions flow again.
    assert!(!handle.infill(req).unwrap().text.contains('_'));
}

/// ACCEPTANCE (satellite b): the deadline clock keeps running while a
/// checkpointed request waits in the resume queue — a preempted/drained
/// request that expires while parked books `deadline_expired` (never
/// `cancelled`) and reports its partial progress "while queued".
#[test]
fn request_expiring_while_parked_books_deadline_expired() {
    let (handle, metrics) = pool(|| SlowEngine::new(mock(), Duration::from_millis(10)), 1);
    let rh = handle
        .submit(InfillRequest {
            text: format!("ab{}cd", "_".repeat(12)),
            sampler: SamplerKind::Sequential,
            seed: 3,
            timeout_ms: Some(300),
            ..Default::default()
        })
        .unwrap();
    // Admitted and progressing...
    assert!(matches!(rh.next_event(), Some(Event::Committed { .. })));
    // ...then parked well inside the deadline.
    handle.set_draining(true);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while handle.parked() == 0 {
        assert!(std::time::Instant::now() < deadline, "never parked");
        std::thread::sleep(Duration::from_millis(2));
    }
    // Let the deadline burn up IN THE PARK (the slot is off-engine; only
    // the submission clock is still running), then lift the drain.
    std::thread::sleep(Duration::from_millis(400));
    handle.set_draining(false);
    let err = rh.wait().unwrap_err().to_string();
    assert!(err.contains("deadline exceeded"), "{err}");
    assert!(err.contains("while queued"), "{err}");
    assert!(err.contains("/12 tokens"), "{err}");
    assert_eq!(metrics.deadline_expired(), 1, "books deadline_expired");
    assert_eq!(metrics.cancelled(), 0, "must NOT book cancelled");
    assert_eq!(metrics.requests(), 0);
}

/// The /drain admin surface over a live socket: POST flips the flag
/// (503 + Retry-After on both infill endpoints while up), GET reports
/// state, `?resume=1` lifts it — and an SSE stream opened BEFORE the
/// drain stays open across park + resume and completes with the full
/// text.
#[test]
fn drain_endpoint_over_http_keeps_streams_open() {
    let metrics = Metrics::new();
    let handle = spawn(
        || {
            Ok(Box::new(SlowEngine::new(mock(), Duration::from_millis(20))) as Box<dyn Engine>)
        },
        SchedulerConfig {
            max_batch: 1,
            idle_poll: Duration::from_millis(1),
            ..Default::default()
        },
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle.clone(), metrics.clone(), 4).unwrap();
    let addr = server.serve_background();

    // A stream in flight before the drain begins: 16 targets at 20 ms per
    // forward is a ~320 ms decode, so the grace sleep below lands the
    // drain mid-flight (after admission, long before completion).
    let body = format!(
        r#"{{"text":"ab{}cd","sampler":"sequential","seed":9}}"#,
        "_".repeat(16)
    );
    let stream_body = body.clone();
    let streamer =
        std::thread::spawn(move || http_post_stream(&addr, "/infill/stream", &stream_body));
    std::thread::sleep(Duration::from_millis(60));

    let (code, resp) = http_post(&addr, "/drain", "").unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("draining").unwrap().as_bool(), Some(true));

    // Both infill endpoints refuse with 503 + Retry-After (not the 429
    // shed — the client must wait out the restart, not just back off).
    let infill = r#"{"text":"ab____cd","seed":1}"#;
    let r = http_post_stream(&addr, "/v1/infill", infill).unwrap();
    assert_eq!(r.status, 503, "{}", r.body);
    assert!(r.header("retry-after").is_some());
    assert!(r.body.contains("draining"), "{}", r.body);
    let r = http_post_stream(&addr, "/infill/stream", infill).unwrap();
    assert_eq!(r.status, 503, "{}", r.body);

    // The in-flight stream parks (visible at GET /drain) but stays open.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let (code, body) = http_get(&addr, "/drain").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        if j.get("parked").unwrap().as_f64() == Some(1.0) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "stream never parked: {body}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let (code, resp) = http_post(&addr, "/drain?resume=1", "").unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("draining").unwrap().as_bool(), Some(false));

    // The parked stream resumed and completed; each target streamed once.
    let stream = streamer.join().unwrap().unwrap();
    assert_eq!(stream.status, 200, "{}", stream.body);
    let done = stream
        .events
        .iter()
        .find(|e| e.event == "done")
        .unwrap_or_else(|| panic!("no done event: {:?}", stream.events));
    let text = Json::parse(&done.data)
        .unwrap()
        .get("text")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert!(!text.contains('_'), "{text}");
    let commits: usize = stream
        .events
        .iter()
        .filter(|e| e.event == "commit")
        .map(|e| {
            Json::parse(&e.data)
                .unwrap()
                .get("positions")
                .unwrap()
                .as_arr()
                .unwrap()
                .len()
        })
        .sum();
    assert_eq!(commits, 16, "each target exactly once across the park");

    // Admissions flow again after the lift.
    let (code, resp) = http_post(&addr, "/v1/infill", infill).unwrap();
    assert_eq!(code, 200, "{resp}");
    assert!(metrics.drains() >= 1);
}

/// Satellite a: the pool's retry budget is a serve-level knob surfaced
/// in every /replicas object.
#[test]
fn replicas_json_carries_retry_budget() {
    let metrics = Metrics::new();
    let handle = spawn(
        || Ok(Box::new(mock()) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch: 2,
            idle_poll: Duration::from_millis(1),
            retry_budget: 3,
            ..Default::default()
        },
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics, 2).unwrap();
    let addr = server.serve_background();
    let (code, body) = http_get(&addr, "/replicas").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    let arr = j.as_arr().expect("array of replicas");
    assert!(!arr.is_empty());
    for r in arr {
        assert_eq!(
            r.get("retry_budget").unwrap().as_f64(),
            Some(3.0),
            "{body}"
        );
    }
}
