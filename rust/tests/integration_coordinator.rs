//! Integration: the full coordinator stack (scheduler + HTTP server) over
//! the mock engine — hermetic, no artifacts needed — plus one real-engine
//! smoke when artifacts exist.

use std::time::Duration;

use asarm::coordinator::http::{http_get, http_post, HttpServer};
use asarm::coordinator::scheduler::{spawn, SchedulerConfig};
use asarm::coordinator::Metrics;
use asarm::runtime::mock::MockEngine;
use asarm::runtime::Engine;
use asarm::util::json::Json;

fn mock_server(max_batch: usize) -> (std::net::SocketAddr, Metrics) {
    let metrics = Metrics::new();
    let m2 = metrics.clone();
    let handle = spawn(
        move || Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch,
            idle_poll: Duration::from_millis(2),
        },
        m2,
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics.clone(), 4).unwrap();
    (server.serve_background(), metrics)
}

#[test]
fn healthz_and_metrics_endpoints() {
    let (addr, _) = mock_server(2);
    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));
    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("requests").is_some());
}

#[test]
fn infill_roundtrip_over_http() {
    let (addr, metrics) = mock_server(2);
    let body = r#"{"text":"ab____cd","sampler":"assd","k":4,"seed":3}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert!(!j.get("text").unwrap().as_str().unwrap().contains('_'));
    assert!(j.get("model_nfe").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(metrics.requests(), 1);
}

#[test]
fn bad_requests_get_400() {
    let (addr, _) = mock_server(1);
    for body in [
        "not json",
        r#"{"no_text": 1}"#,
        r#"{"text":"x","sampler":"nope"}"#,
    ] {
        let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
        assert_eq!(code, 400, "{body} -> {resp}");
        assert!(resp.contains("error"));
    }
    let (code, _) = http_get(&addr, "/nothing-here").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn concurrent_http_load_is_consistent() {
    let (addr, metrics) = mock_server(4);
    let pool = asarm::util::threadpool::ThreadPool::new(6);
    let jobs: Vec<_> = (0..12)
        .map(|i| {
            move || {
                let body = format!(r#"{{"text":"xy______z","seed":{i}}}"#);
                let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
                assert_eq!(code, 200, "{resp}");
                let j = Json::parse(&resp).unwrap();
                assert_eq!(j.get("n_generated").unwrap().as_f64(), Some(6.0));
            }
        })
        .collect();
    pool.scoped_run(jobs);
    assert_eq!(metrics.requests(), 12);
    // Theorem 1 at the fleet level: total model NFE <= total tokens
    // (every request here uses the self-drafting ASSD default).
    let j = metrics.snapshot_json();
    let nfe = j.get("model_nfe").unwrap().as_f64().unwrap();
    let toks = j.get("tokens_generated").unwrap().as_f64().unwrap();
    assert!(nfe <= toks, "fleet NFE {nfe} > tokens {toks}");
}

#[test]
fn sequential_vs_assd_nfe_over_http() {
    let (addr, _) = mock_server(2);
    let get_nfe = |sampler: &str| -> f64 {
        let body = format!(
            r#"{{"text":"ab{}cd","sampler":"{sampler}","k":5,"seed":9}}"#,
            "_".repeat(20)
        );
        let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
        Json::parse(&resp)
            .unwrap()
            .get("model_nfe")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let seq = get_nfe("sequential");
    let assd = get_nfe("assd");
    assert_eq!(seq, 20.0);
    assert!(assd <= 20.0, "ASSD used {assd} NFE > sequential {seq}");
}

/// Real-engine smoke: full HTTP round trip through the XLA engine.
#[test]
fn real_engine_http_smoke() {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(artifacts).join("fwd_b1.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let metrics = Metrics::new();
    let handle = asarm::coordinator::start_xla(
        artifacts,
        None,
        SchedulerConfig::default(),
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics, 2).unwrap();
    let addr = server.serve_background();
    let (code, resp) =
        http_post(&addr, "/v1/infill", r#"{"text":"Tom went to the ____.","seed":1}"#).unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    let nfe = j.get("model_nfe").unwrap().as_f64().unwrap();
    assert!((1.0..=4.0).contains(&nfe), "nfe={nfe}");
}
