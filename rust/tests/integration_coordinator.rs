//! Integration: the full coordinator stack (engine pool + scheduler
//! workers + HTTP server) over mock engines — hermetic, no artifacts
//! needed — plus one real-engine smoke when artifacts exist. Includes
//! the streaming lifecycle surface: SSE over a real socket, queue-full
//! shedding (429), and client-disconnect cancellation.

use std::time::Duration;

use anyhow::bail;
use asarm::coordinator::http::{http_get, http_get_accept, http_post, http_post_stream, HttpServer};
use asarm::coordinator::lifecycle::Event;
use asarm::coordinator::scheduler::{spawn, spawn_pool, SchedulerConfig, SchedulerHandle};
use asarm::coordinator::{InfillRequest, Metrics, ReplicaState};
use asarm::runtime::mock::{MockEngine, SlowEngine};
use asarm::runtime::{Engine, EnginePool, PoolConfig};
use asarm::util::json::Json;

fn mock_server(max_batch: usize) -> (std::net::SocketAddr, Metrics) {
    let metrics = Metrics::new();
    let m2 = metrics.clone();
    let handle = spawn(
        move || Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch,
            idle_poll: Duration::from_millis(2),
            ..Default::default()
        },
        m2,
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics.clone(), 4).unwrap();
    (server.serve_background(), metrics)
}

/// A pool of MockEngine replicas; replica ids listed in `fail` refuse to
/// provision (simulating a dead/misconfigured replica).
fn mock_pool(replicas: usize, max_batch: usize, fail: &[usize]) -> (SchedulerHandle, Metrics) {
    let metrics = Metrics::new();
    let fail: Vec<usize> = fail.to_vec();
    // Identical seed for every replica: they are copies of one model.
    let pool = EnginePool::from_fn(PoolConfig { replicas }, move |id| {
        if fail.contains(&id) {
            bail!("replica {id} configured to fail");
        }
        Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>)
    });
    let handle = spawn_pool(
        pool,
        SchedulerConfig {
            max_batch,
            idle_poll: Duration::from_millis(2),
            ..Default::default()
        },
        metrics.clone(),
    );
    (handle, metrics)
}

#[test]
fn healthz_and_metrics_endpoints() {
    let (addr, _) = mock_server(2);
    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));
    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("requests").is_some());
}

#[test]
fn infill_roundtrip_over_http() {
    let (addr, metrics) = mock_server(2);
    let body = r#"{"text":"ab____cd","sampler":"assd","k":4,"seed":3}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert!(!j.get("text").unwrap().as_str().unwrap().contains('_'));
    assert!(j.get("model_nfe").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(metrics.requests(), 1);
}

#[test]
fn bad_requests_get_400() {
    let (addr, _) = mock_server(1);
    for body in [
        "not json",
        r#"{"no_text": 1}"#,
        r#"{"text":"x","sampler":"nope"}"#,
    ] {
        let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
        assert_eq!(code, 400, "{body} -> {resp}");
        assert!(resp.contains("error"));
    }
    let (code, _) = http_get(&addr, "/nothing-here").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn concurrent_http_load_is_consistent() {
    let (addr, metrics) = mock_server(4);
    let pool = asarm::util::threadpool::ThreadPool::new(6);
    let jobs: Vec<_> = (0..12)
        .map(|i| {
            move || {
                let body = format!(r#"{{"text":"xy______z","seed":{i}}}"#);
                let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
                assert_eq!(code, 200, "{resp}");
                let j = Json::parse(&resp).unwrap();
                assert_eq!(j.get("n_generated").unwrap().as_f64(), Some(6.0));
            }
        })
        .collect();
    pool.scoped_run(jobs);
    assert_eq!(metrics.requests(), 12);
    // Theorem 1 at the fleet level: total model NFE <= total tokens
    // (every request here uses the self-drafting ASSD default).
    let j = metrics.snapshot_json();
    let nfe = j.get("model_nfe").unwrap().as_f64().unwrap();
    let toks = j.get("tokens_generated").unwrap().as_f64().unwrap();
    assert!(nfe <= toks, "fleet NFE {nfe} > tokens {toks}");
}

/// The draft subsystem over HTTP: per-kind requests round-trip, report
/// speculation telemetry, and the accept-rate shows up in /metrics and
/// /replicas.
#[test]
fn draft_field_and_speculation_telemetry_over_http() {
    let (addr, _) = mock_server(2);
    for (kind, adaptive) in [("self", true), ("bigram", false), ("lookup", false)] {
        let body = format!(
            r#"{{"text":"ab________cd","sampler":"assd","seed":4,
                "draft":{{"kind":"{kind}","max_len":4,"adaptive":{adaptive}}}}}"#
        );
        let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("draft").unwrap().as_str(), Some(kind));
        assert!(!j.get("text").unwrap().as_str().unwrap().contains('_'));
        assert!(j.get("proposed").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("draft_len").unwrap().as_f64().unwrap() >= 1.0);
    }
    // unknown draft kind is a 400 that names the valid ones
    let (code, resp) = http_post(
        &addr,
        "/v1/infill",
        r#"{"text":"a__b","draft":{"kind":"bogus"}}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    assert!(resp.contains("lookup"), "error should list kinds: {resp}");
    let (_, m) = http_get(&addr, "/metrics").unwrap();
    let j = Json::parse(&m).unwrap();
    assert!(j.get("proposed").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("acceptance_rate").unwrap().as_f64().unwrap() > 0.0);
}

/// Per-replica speculation counters are exported at /replicas and sum to
/// the aggregate.
#[test]
fn replica_speculation_counters_sum_to_aggregate() {
    let (handle, metrics) = mock_pool(2, 2, &[]);
    let handles: Vec<_> = (0..10)
        .map(|i| {
            handle
                .submit(InfillRequest {
                    text: "xy______z".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rh in handles {
        rh.wait().unwrap();
    }
    let stats = handle.replica_stats();
    let prop_sum: u64 = stats.iter().map(|r| r.proposed()).sum();
    let acc_sum: u64 = stats.iter().map(|r| r.accepted()).sum();
    let j = metrics.snapshot_json();
    assert_eq!(prop_sum as f64, j.get("proposed").unwrap().as_f64().unwrap());
    assert_eq!(acc_sum as f64, j.get("accepted").unwrap().as_f64().unwrap());
    assert!(prop_sum > 0);
    for r in stats {
        let s = r.snapshot_json();
        assert!(s.get("acceptance_rate").is_some());
        assert!(s.get("proposed").is_some());
    }
}

#[test]
fn sequential_vs_assd_nfe_over_http() {
    let (addr, _) = mock_server(2);
    let get_nfe = |sampler: &str| -> f64 {
        let body = format!(
            r#"{{"text":"ab{}cd","sampler":"{sampler}","k":5,"seed":9}}"#,
            "_".repeat(20)
        );
        let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
        Json::parse(&resp)
            .unwrap()
            .get("model_nfe")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let seq = get_nfe("sequential");
    let assd = get_nfe("assd");
    assert_eq!(seq, 20.0);
    assert!(assd <= 20.0, "ASSD used {assd} NFE > sequential {seq}");
}

// --- engine-pool integration -------------------------------------------

/// Requests must spread across workers: with per-worker batch slots of 1
/// and a deep backlog of multi-iteration decodes, a single worker cannot
/// plausibly win every dequeue race.
#[test]
fn pool_serves_requests_across_multiple_workers() {
    let (handle, metrics) = mock_pool(2, 1, &[]);
    let handles: Vec<_> = (0..32)
        .map(|i| {
            handle
                .submit(InfillRequest {
                    text: "ab________cd".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rh in handles {
        let resp = rh.wait().unwrap();
        assert_eq!(resp.n_generated, 8);
    }
    assert_eq!(metrics.requests(), 32);
    let active = handle
        .replica_stats()
        .iter()
        .filter(|r| r.requests() > 0)
        .count();
    assert!(
        active >= 2,
        "expected >=2 workers to serve, got {active} (per-replica: {:?})",
        handle
            .replica_stats()
            .iter()
            .map(|r| r.requests())
            .collect::<Vec<_>>()
    );
}

/// The pool-level aggregate must equal the sum of per-worker counters.
#[test]
fn pool_aggregate_metrics_equal_sum_of_replica_stats() {
    let (handle, metrics) = mock_pool(3, 2, &[]);
    let handles: Vec<_> = (0..24)
        .map(|i| {
            handle
                .submit(InfillRequest {
                    text: "xy______z".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rh in handles {
        rh.wait().unwrap();
    }
    let stats = handle.replica_stats();
    assert_eq!(stats.len(), 3);
    let req_sum: u64 = stats.iter().map(|r| r.requests()).sum();
    let tok_sum: u64 = stats.iter().map(|r| r.tokens_generated()).sum();
    let nfe_sum: u64 = stats.iter().map(|r| r.model_nfe()).sum();
    let iter_sum: u64 = stats.iter().map(|r| r.batch_iterations()).sum();
    let j = metrics.snapshot_json();
    assert_eq!(req_sum, metrics.requests());
    assert_eq!(
        tok_sum as f64,
        j.get("tokens_generated").unwrap().as_f64().unwrap()
    );
    assert_eq!(nfe_sum as f64, j.get("model_nfe").unwrap().as_f64().unwrap());
    assert_eq!(
        iter_sum as f64,
        j.get("batch_iterations").unwrap().as_f64().unwrap()
    );
}

/// A replica that fails to provision drains out without consuming jobs:
/// the shared admission queue keeps flowing through the healthy workers.
#[test]
fn pool_survives_failed_replica_without_stalling_queue() {
    let (handle, metrics) = mock_pool(3, 2, &[1]);
    let handles: Vec<_> = (0..12)
        .map(|i| {
            handle
                .submit(InfillRequest {
                    text: "ab____cd".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rh in handles {
        let resp = rh.wait().unwrap();
        assert_eq!(resp.n_generated, 4);
    }
    assert_eq!(metrics.requests(), 12);
    let stats = handle.replica_stats();
    assert_eq!(stats[1].requests(), 0, "failed replica served requests");
    // The worker records its failure state (visible at GET /replicas);
    // poll briefly since the state flips on the worker thread.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while stats[1].state() != ReplicaState::Failed {
        assert!(
            std::time::Instant::now() < deadline,
            "replica 1 never reported Failed (state {:?})",
            stats[1].state()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// /replicas over HTTP: one JSON object per replica with id + counters.
#[test]
fn replicas_endpoint_reports_per_worker_stats() {
    let (handle, metrics) = mock_pool(2, 2, &[]);
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics, 4).unwrap();
    let addr = server.serve_background();
    let body = r#"{"text":"ab____cd","seed":1}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let (code, body) = http_get(&addr, "/replicas").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    let arr = j.as_arr().expect("array of replicas");
    assert_eq!(arr.len(), 2);
    for (i, r) in arr.iter().enumerate() {
        assert_eq!(r.get("replica").unwrap().as_usize(), Some(i));
        assert!(r.get("state").unwrap().as_str().is_some());
        assert!(r.get("requests").unwrap().as_f64().is_some());
    }
    let served: f64 = arr
        .iter()
        .map(|r| r.get("requests").unwrap().as_f64().unwrap())
        .sum();
    assert_eq!(served, 1.0);
}

// --- observability surfaces over a real socket -------------------------

/// GET /metrics content negotiation: `Accept: text/plain` serves the
/// Prometheus text exposition (pool counters AND per-replica series);
/// no Accept header keeps serving the JSON snapshot unchanged.
#[test]
fn metrics_content_negotiation_serves_prometheus_text() {
    let (addr, _) = mock_server(2);
    let body = r#"{"text":"ab____cd","seed":11}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    // Default stays JSON — existing dashboards parse this.
    let (code, json_body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(Json::parse(&json_body).is_ok(), "JSON default broke");
    // A scraper's Accept list flips the representation.
    let (code, text) =
        http_get_accept(&addr, "/metrics", "text/plain;version=0.0.4, */*;q=0.1").unwrap();
    assert_eq!(code, 200);
    assert!(
        text.contains("# TYPE asarm_requests_total counter"),
        "missing TYPE line:\n{text}"
    );
    assert!(text.contains("asarm_requests_total 1"), "{text}");
    assert!(text.contains("asarm_tokens_generated_total 4"), "{text}");
    // Per-phase latency series and per-replica series are present.
    assert!(text.contains(r#"asarm_phase_seconds_count{phase="forward"}"#), "{text}");
    assert!(
        text.contains(r#"asarm_replica_requests_total{replica="0"} 1"#),
        "{text}"
    );
    // Every sample line is `name[{labels}] value` — no JSON leakage.
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        assert!(
            line.starts_with("asarm_") && line.rsplit(' ').next().unwrap().parse::<f64>().is_ok(),
            "malformed exposition line: {line:?}"
        );
    }
}

/// GET /trace/{id} serves Chrome trace-event JSON for a finished
/// request; /trace/recent indexes it; unknown ids 404 and junk ids 400.
#[test]
fn trace_endpoints_serve_chrome_json_and_index() {
    let (addr, _) = mock_server(2);
    let body = r#"{"text":"ab________cd","sampler":"assd","seed":21,
                   "draft":{"kind":"bigram","max_len":4}}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let id = Json::parse(&resp)
        .unwrap()
        .get("request_id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    assert!(id > 0, "response must carry the trace key");

    let (code, trace) = http_get(&addr, &format!("/trace/{id}")).unwrap();
    assert_eq!(code, 200, "{trace}");
    let j = Json::parse(&trace).expect("chrome trace must be valid JSON");
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    // Duration events must carry monotone non-negative timestamps.
    let mut saw_forward = false;
    for ev in events {
        if ev.get("ph").unwrap().as_str() == Some("X") {
            assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            if ev.get("name").unwrap().as_str() == Some("forward") {
                saw_forward = true;
            }
        }
    }
    assert!(saw_forward, "no forward span in {trace}");

    let (code, recent) = http_get(&addr, "/trace/recent").unwrap();
    assert_eq!(code, 200);
    let arr = Json::parse(&recent).unwrap();
    let arr = arr.as_arr().unwrap();
    assert!(arr
        .iter()
        .any(|t| t.get("request_id").unwrap().as_f64() == Some(id as f64)));

    let (code, miss) = http_get(&addr, "/trace/18446744073709551614").unwrap();
    assert_eq!(code, 404, "{miss}");
    assert!(miss.contains("no trace"), "{miss}");
    let (code, junk) = http_get(&addr, "/trace/not-a-number").unwrap();
    assert_eq!(code, 400, "{junk}");
}

/// A server that records every request's speculation flight (sample rate
/// 1.0) — the /debug surfaces need guaranteed records to assert against.
fn flight_server() -> (std::net::SocketAddr, Metrics) {
    let metrics = Metrics::new();
    let handle = spawn(
        move || Ok(Box::new(MockEngine::new(5, 32, 258, 1.0)) as Box<dyn Engine>),
        SchedulerConfig {
            max_batch: 2,
            idle_poll: Duration::from_millis(2),
            flight_sample_rate: 1.0,
            ..Default::default()
        },
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics.clone(), 4).unwrap();
    (server.serve_background(), metrics)
}

/// GET /trace/recent?limit=N bounds the index (clamped to the ring
/// capacity) and junk limits are a 400, not a silent default.
#[test]
fn trace_recent_limit_param_clamps_and_rejects_junk() {
    let (addr, _) = mock_server(2);
    for seed in 0..3 {
        let body = format!(r#"{{"text":"ab____cd","seed":{seed}}}"#);
        let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
    }
    let (code, body) = http_get(&addr, "/trace/recent?limit=1").unwrap();
    assert_eq!(code, 200, "{body}");
    let arr = Json::parse(&body).unwrap();
    assert_eq!(arr.as_arr().unwrap().len(), 1, "{body}");
    // An absurd limit is clamped to the ring capacity, not an error.
    let (code, body) = http_get(&addr, "/trace/recent?limit=999999999").unwrap();
    assert_eq!(code, 200, "{body}");
    assert!(Json::parse(&body).unwrap().as_arr().unwrap().len() >= 3);
    for junk in ["abc", "-1", "1.5", ""] {
        let (code, body) = http_get(&addr, &format!("/trace/recent?limit={junk}")).unwrap();
        assert_eq!(code, 400, "limit={junk:?} -> {body}");
        assert!(body.contains("error"), "{body}");
    }
    // No query at all keeps the default behavior.
    let (code, _) = http_get(&addr, "/trace/recent").unwrap();
    assert_eq!(code, 200);
}

/// ACCEPTANCE: /debug/vars and /debug/dashboard are served end-to-end
/// over a live socket, and /debug/flight/{id} round-trips a sampled
/// request's speculation anatomy (404 on misses, 400 on junk ids).
#[test]
fn debug_endpoints_serve_vars_flight_and_dashboard() {
    let (addr, _) = flight_server();
    let body = r#"{"text":"ab________cd","sampler":"assd","seed":23,
                   "draft":{"kind":"bigram","max_len":4}}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let id = Json::parse(&resp)
        .unwrap()
        .get("request_id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;

    let (code, vars) = http_get(&addr, "/debug/vars").unwrap();
    assert_eq!(code, 200, "{vars}");
    let j = Json::parse(&vars).expect("debug vars must be valid JSON");
    assert!(
        !j.get("series").unwrap().as_arr().unwrap().is_empty(),
        "time-series empty after serving traffic: {vars}"
    );
    let heat = j.get("heatmap").unwrap().as_arr().unwrap();
    assert!(
        heat.iter()
            .any(|h| h.get("drafter").unwrap().as_str() == Some("bigram")),
        "heatmap missing the bigram drafter: {vars}"
    );
    assert!(j.get("queue_depth").is_some(), "{vars}");
    let (code, _) = http_get(&addr, "/debug/vars?window=5").unwrap();
    assert_eq!(code, 200);
    let (code, body) = http_get(&addr, "/debug/vars?window=soon").unwrap();
    assert_eq!(code, 400, "{body}");

    let (code, flight) = http_get(&addr, &format!("/debug/flight/{id}")).unwrap();
    assert_eq!(code, 200, "{flight}");
    let f = Json::parse(&flight).unwrap();
    assert_eq!(f.get("request_id").unwrap().as_f64(), Some(id as f64));
    assert_eq!(f.get("drafter").unwrap().as_str(), Some("bigram"));
    assert!(
        !f.get("windows").unwrap().as_arr().unwrap().is_empty(),
        "{flight}"
    );
    assert!(f.get("window_trajectory").is_some(), "{flight}");
    let (code, miss) = http_get(&addr, "/debug/flight/18446744073709551614").unwrap();
    assert_eq!(code, 404, "{miss}");
    assert!(miss.contains("no flight record"), "{miss}");
    let (code, _) = http_get(&addr, "/debug/flight/nope").unwrap();
    assert_eq!(code, 400);

    let (code, page) = http_get(&addr, "/debug/dashboard").unwrap();
    assert_eq!(code, 200);
    assert!(page.contains("<!doctype html"), "not an HTML page");
    assert!(
        page.contains("/debug/vars"),
        "dashboard must poll /debug/vars"
    );
    assert!(!page.contains("http://"), "dashboard must be self-contained");
}

/// Line-by-line lint of the whole /metrics text exposition against the
/// Prometheus 0.0.4 grammar: every line is HELP/TYPE/sample, every
/// sample's family is declared by a preceding TYPE (histogram suffixes
/// resolve to their base family, `_bucket` carries `le`), metric and
/// label names match the spec charset, label values are quoted with only
/// legal escapes, and values parse.
#[test]
fn prometheus_exposition_passes_0_0_4_lint() {
    let (addr, _) = flight_server();
    let body = r#"{"text":"ab________cd","sampler":"assd","seed":31,
                   "draft":{"kind":"bigram","max_len":4}}"#;
    let (code, resp) = http_post(&addr, "/v1/infill", body).unwrap();
    assert_eq!(code, 200, "{resp}");
    let (code, text) = http_get_accept(&addr, "/metrics", "text/plain").unwrap();
    assert_eq!(code, 200);
    // The flight families must be part of the linted output.
    assert!(text.contains("asarm_flight_position_proposed_total{drafter="));
    assert_prometheus_0_0_4(&text);
}

/// Minimal 0.0.4 grammar checker (see the lint test above).
fn assert_prometheus_0_0_4(text: &str) {
    use std::collections::{HashMap, HashSet};
    fn name_ok(n: &str) -> bool {
        let mut chars = n.chars();
        match chars.next() {
            Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
            _ => return false,
        }
        chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    let mut types: HashMap<String, String> = HashMap::new();
    let mut helps: HashSet<String> = HashSet::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            assert!(name_ok(name), "bad family name in HELP: {line:?}");
            assert!(helps.insert(name.to_string()), "duplicate HELP: {name}");
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            assert!(name_ok(name), "bad family name in TYPE: {line:?}");
            assert!(
                ["counter", "gauge", "histogram", "summary", "untyped"].contains(&kind),
                "bad TYPE kind: {line:?}"
            );
            assert!(
                types.insert(name.to_string(), kind.to_string()).is_none(),
                "duplicate TYPE: {name}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment line: {line:?}");
        let (name_labels, value) = line.rsplit_once(' ').expect("sample needs a value");
        assert!(
            value.parse::<f64>().is_ok() || ["+Inf", "-Inf", "NaN"].contains(&value),
            "bad sample value: {line:?}"
        );
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, rest)) => {
                assert!(rest.ends_with('}'), "unterminated label block: {line:?}");
                (n, Some(&rest[..rest.len() - 1]))
            }
            None => (name_labels, None),
        };
        assert!(name_ok(name), "bad metric name: {line:?}");
        if let Some(labels) = labels {
            let mut chars = labels.chars();
            'pairs: loop {
                let mut key = String::new();
                for c in chars.by_ref() {
                    if c == '=' {
                        break;
                    }
                    key.push(c);
                }
                assert!(name_ok(&key), "bad label name {key:?} in {line:?}");
                assert_eq!(chars.next(), Some('"'), "label value not quoted: {line:?}");
                let mut closed = false;
                while let Some(c) = chars.next() {
                    match c {
                        '\\' => {
                            let e = chars.next().expect("dangling backslash");
                            assert!(
                                ['\\', '"', 'n'].contains(&e),
                                "illegal escape \\{e} in {line:?}"
                            );
                        }
                        '"' => {
                            closed = true;
                            break;
                        }
                        _ => {}
                    }
                }
                assert!(closed, "unterminated label value: {line:?}");
                match chars.next() {
                    None => break 'pairs,
                    Some(',') => continue 'pairs,
                    Some(c) => panic!("unexpected {c:?} after label value: {line:?}"),
                }
            }
        }
        // Every sample must belong to a family declared by a preceding
        // TYPE; histogram series expose _bucket/_sum/_count suffixes.
        let family = types
            .iter()
            .find(|(f, kind)| {
                name == f.as_str()
                    || (kind.as_str() == "histogram"
                        && [
                            format!("{f}_bucket"),
                            format!("{f}_sum"),
                            format!("{f}_count"),
                        ]
                        .iter()
                        .any(|s| s == name))
            })
            .map(|(f, _)| f.clone())
            .unwrap_or_else(|| panic!("sample {name} has no preceding # TYPE"));
        if types[&family] == "histogram" && name == format!("{family}_bucket") {
            assert!(
                labels.unwrap_or("").contains("le="),
                "histogram bucket without le label: {line:?}"
            );
        }
    }
    assert!(!types.is_empty(), "exposition declared no families");
    for f in types.keys() {
        assert!(helps.contains(f), "TYPE without HELP: {f}");
    }
}

// --- streaming lifecycle over a real socket ----------------------------

/// A server whose engine sleeps per forward: slow enough to observe
/// shedding and disconnect-cancellation deterministically over HTTP.
fn slow_server(
    max_batch: usize,
    queue_depth: usize,
    delay_ms: u64,
) -> (std::net::SocketAddr, SchedulerHandle, Metrics) {
    let metrics = Metrics::new();
    let handle = spawn(
        move || {
            Ok(Box::new(SlowEngine::new(
                MockEngine::new(5, 32, 258, 1.0),
                Duration::from_millis(delay_ms),
            )) as Box<dyn Engine>)
        },
        SchedulerConfig {
            max_batch,
            queue_depth,
            idle_poll: Duration::from_millis(2),
            ..Default::default()
        },
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle.clone(), metrics.clone(), 4).unwrap();
    (server.serve_background(), handle, metrics)
}

/// ACCEPTANCE: the SSE stream reassembles to exactly the blocking-path
/// text for the same seed — for all three decode machines and every
/// drafter — and the concatenated `text_delta`s match too.
#[test]
fn sse_stream_reassembles_to_blocking_text_for_all_machines() {
    let (addr, _metrics) = mock_server(2);
    let configs: &[(&str, &str)] = &[
        ("assd", "self"),
        ("assd", "bigram"),
        ("assd", "lookup"),
        ("sequential", "self"),
        ("diffusion", "self"),
    ];
    let text = "ab________cd";
    for (sampler, draft) in configs {
        let body = format!(
            r#"{{"text":"{text}","sampler":"{sampler}","seed":17,
                "draft":{{"kind":"{draft}","max_len":4}}}}"#
        );
        let (code, blocking) = http_post(&addr, "/v1/infill", &body).unwrap();
        assert_eq!(code, 200, "{blocking}");
        let blocking_text = Json::parse(&blocking)
            .unwrap()
            .get("text")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();

        let resp = http_post_stream(&addr, "/infill/stream", &body).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(
            resp.header("content-type"),
            Some("text/event-stream"),
            "not SSE"
        );
        let mut bytes = text.as_bytes().to_vec();
        let mut deltas = String::new();
        let mut commits = 0usize;
        let mut done_text = None;
        for ev in &resp.events {
            let j = Json::parse(&ev.data).unwrap();
            match ev.event.as_str() {
                "commit" => {
                    let ps = j.get("positions").unwrap().as_arr().unwrap();
                    let ts = j.get("tokens").unwrap().as_arr().unwrap();
                    for (p, t) in ps.iter().zip(ts) {
                        bytes[p.as_usize().unwrap()] = t.as_usize().unwrap() as u8;
                        commits += 1;
                    }
                    deltas.push_str(j.get("text_delta").unwrap().as_str().unwrap());
                }
                "done" => {
                    done_text = Some(j.get("text").unwrap().as_str().unwrap().to_string());
                }
                other => panic!("unexpected event {other}: {}", ev.data),
            }
        }
        let tag = format!("{sampler}/{draft}");
        assert_eq!(commits, 8, "{tag}: each target streamed exactly once");
        assert_eq!(done_text.as_deref(), Some(blocking_text.as_str()), "{tag}");
        assert_eq!(
            String::from_utf8_lossy(&bytes).into_owned(),
            blocking_text,
            "{tag}: positional reassembly diverged"
        );
        assert_eq!(deltas, blocking_text, "{tag}: delta stream diverged");
    }
    // TTFT / ITL made it into the aggregate metrics
    let (_, m) = http_get(&addr, "/metrics").unwrap();
    let j = Json::parse(&m).unwrap();
    assert!(j.get("ttft_mean_s").unwrap().as_f64().unwrap() > 0.0);
}

/// ACCEPTANCE: a full admission queue sheds with 429 + Retry-After on
/// BOTH infill endpoints, and /metrics counts every shed.
#[test]
fn queue_full_returns_429_with_retry_after_and_counts_shed() {
    let (addr, handle, metrics) = slow_server(1, 1, 20);
    let long = format!("ab{}cd", "_".repeat(12));
    // Occupy the only batch slot (first commit proves admission)...
    let in_slot = handle
        .submit(InfillRequest {
            text: long.clone(),
            seed: 1,
            sampler: asarm::coordinator::SamplerKind::Sequential,
            ..Default::default()
        })
        .unwrap();
    assert!(matches!(in_slot.next_event(), Some(Event::Committed { .. })));
    // ...fill the queue (depth 1)...
    let _queued = handle
        .submit(InfillRequest {
            text: "ab____cd".into(),
            seed: 2,
            ..Default::default()
        })
        .unwrap();
    // ...then both HTTP endpoints must shed.
    let body = r#"{"text":"ab____cd","seed":3}"#;
    let resp = http_post_stream(&addr, "/v1/infill", body).unwrap();
    assert_eq!(resp.status, 429, "{}", resp.body);
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert!(resp.body.contains("queue full"), "{}", resp.body);
    let resp = http_post_stream(&addr, "/infill/stream", body).unwrap();
    assert_eq!(resp.status, 429, "{}", resp.body);
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert_eq!(metrics.shed(), 2);
    let (_, m) = http_get(&addr, "/metrics").unwrap();
    let j = Json::parse(&m).unwrap();
    assert_eq!(j.get("shed").unwrap().as_f64(), Some(2.0));
}

/// A client that disconnects mid-stream flips the cancel token: the
/// scheduler frees the slot and books a cancellation instead of decoding
/// to completion.
#[test]
fn client_disconnect_mid_stream_cancels_request() {
    use std::io::{Read, Write};
    let (addr, _handle, metrics) = slow_server(1, 16, 10);
    let body = format!(r#"{{"text":"ab{}cd","sampler":"sequential","seed":4}}"#, "_".repeat(12));
    let mut socket = std::net::TcpStream::connect(addr).unwrap();
    let req = format!(
        "POST /infill/stream HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    socket.write_all(req.as_bytes()).unwrap();
    socket
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    // Read until the first commit event proves the decode is mid-flight,
    // then vanish without a trace.
    let mut seen = Vec::new();
    let mut buf = [0u8; 1024];
    while !String::from_utf8_lossy(&seen).contains("event: commit") {
        let n = socket.read(&mut buf).unwrap();
        assert!(n > 0, "server closed before first commit");
        seen.extend_from_slice(&buf[..n]);
    }
    drop(socket);
    // The server notices on its next write (or keepalive) and cancels.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while metrics.cancelled() == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "disconnect never cancelled the request"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(metrics.requests(), 0, "cancelled decode must not complete");
}

/// The blocking endpoint also notices a vanished client (socket probe
/// between events): the motivating "dead client occupies a batch slot
/// forever" failure is fixed on BOTH endpoints.
#[test]
fn client_disconnect_on_blocking_endpoint_cancels_request() {
    use std::io::Write;
    let (addr, _handle, metrics) = slow_server(1, 16, 10);
    let body = format!(r#"{{"text":"ab{}cd","sampler":"sequential","seed":6}}"#, "_".repeat(12));
    let mut socket = std::net::TcpStream::connect(addr).unwrap();
    let req = format!(
        "POST /v1/infill HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    socket.write_all(req.as_bytes()).unwrap();
    // Vanish without reading the response: the server must cancel the
    // decode instead of running it to completion for nobody.
    drop(socket);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while metrics.cancelled() == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "blocking disconnect never cancelled the request"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(metrics.requests(), 0, "cancelled decode must not complete");
}

/// Deadline expiry over HTTP: the blocking endpoint reports the partial
/// progress and /metrics counts it.
#[test]
fn timeout_ms_expires_over_http_with_partial_progress() {
    let (addr, _handle, metrics) = slow_server(1, 16, 10);
    let body = format!(
        r#"{{"text":"ab{}cd","sampler":"sequential","seed":5,"timeout_ms":45}}"#,
        "_".repeat(12)
    );
    let (code, resp) = http_post(&addr, "/v1/infill", &body).unwrap();
    assert_eq!(code, 400, "{resp}");
    assert!(resp.contains("deadline exceeded"), "{resp}");
    assert!(resp.contains("/12 tokens"), "{resp}");
    assert_eq!(metrics.deadline_expired(), 1);
}

/// Real-engine smoke: full HTTP round trip through the XLA engine.
#[test]
fn real_engine_http_smoke() {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(artifacts).join("fwd_b1.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let metrics = Metrics::new();
    let handle = asarm::coordinator::start_xla(
        artifacts,
        None,
        PoolConfig::default(),
        SchedulerConfig::default(),
        metrics.clone(),
    );
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics, 2).unwrap();
    let addr = server.serve_background();
    let (code, resp) =
        http_post(&addr, "/v1/infill", r#"{"text":"Tom went to the ____.","seed":1}"#).unwrap();
    assert_eq!(code, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    let nfe = j.get("model_nfe").unwrap().as_f64().unwrap();
    assert!((1.0..=4.0).contains(&nfe), "nfe={nfe}");
}
