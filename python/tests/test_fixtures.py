"""Committed-fixture parity gate (deliberately hypothesis-free so it runs
even where hypothesis is not installed — unlike test_masks.py)."""

import json
import os

from compile import masks as M

FIXTURE = os.path.join(
    os.path.dirname(__file__), "..", "..", "artifacts", "fixtures", "masks.json"
)


def test_fixture_file_matches_builders():
    """The COMMITTED golden fixture (consumed byte-for-byte by `cargo
    test`) must itself match the python builders — regenerating it with
    `make fixtures` after a semantic change is mandatory, not optional."""
    with open(FIXTURE) as f:
        cases = json.load(f)
    assert len(cases) >= 10
    draft_cases = 0
    for c in cases:
        m, sigma = c["m"], c["sigma"]
        vh, vg = M.verify_masks(sigma, m)
        assert vh.astype(int).flatten().tolist() == c["verify_h"]
        assert vg.astype(int).flatten().tolist() == c["verify_g"]
        assert c["drafts"], "every fixture case carries a draft sweep"
        order = M.order_from_sigma(sigma)
        for d in c["drafts"]:
            dh, dg = M.draft_masks(sigma, m, d["n_known"])
            assert dh.astype(int).flatten().tolist() == d["h"]
            assert dg.astype(int).flatten().tolist() == d["g"]
            # the on-device constructor reference agrees too
            oh, og = M.masks_from_order(order, m, d["n_known"])
            assert (oh == dh).all() and (og == dg).all()
            draft_cases += 1
    assert draft_cases >= 20, "draft sweep too thin"


def test_fixture_regenerates_byte_identically(tmp_path):
    """fixtures.py with the default seed must reproduce the committed file
    byte-for-byte (determinism is what makes the commit reviewable)."""
    from compile.fixtures import export_mask_fixtures

    out = tmp_path / "masks.json"
    export_mask_fixtures(None, str(out))
    assert out.read_bytes() == open(FIXTURE, "rb").read()
