"""AOT export sanity: HLO text parses and has the expected interface."""

import re

import pytest

from compile import aot
from compile.config import TINY, DEFAULT


@pytest.fixture(scope="module")
def fwd_text():
    return aot.export_forward(TINY, 1)


def test_forward_hlo_has_entry(fwd_text):
    assert "ENTRY" in fwd_text
    assert "HloModule" in fwd_text


def test_forward_hlo_parameters(fwd_text):
    # theta, tokens, mask_h, mask_g
    n, v, p = TINY.seq_len, TINY.vocab, TINY.n_params
    assert f"f32[{p}]" in fwd_text
    assert f"s32[1,{n}]" in fwd_text
    assert f"f32[1,{n},{n}]" in fwd_text
    # output logits
    assert f"f32[1,{n},{v}]" in fwd_text


def test_forward_ord_hlo_interface():
    rows = 4
    text = aot.export_forward_ord(TINY, 1, rows)
    n, v, p = TINY.seq_len, TINY.vocab, TINY.n_params
    assert "ENTRY" in text
    # theta + the compact index inputs (tokens/order [1,N], want [1,R])
    assert f"f32[{p}]" in text
    assert f"s32[1,{n}]" in text
    assert f"s32[1,{rows}]" in text
    # gathered output rows, NOT the full [N, V] grid
    assert f"f32[1,{rows},{v}]" in text


def test_train_step_hlo_outputs():
    text = aot.export_train_step(TINY, 2)
    p = TINY.n_params
    assert "ENTRY" in text
    # tuple of theta', m', v', loss
    assert re.search(r"f32\[%d\].*f32\[%d\].*f32\[%d\].*f32\[\]" % (p, p, p), text) or (
        f"f32[{p}]" in text and "f32[]" in text
    )


def test_meta_json_roundtrip(tmp_path):
    import json

    meta = json.loads(DEFAULT.meta_json())
    assert meta["n_params"] == DEFAULT.n_params
    assert meta["params"]["tok_emb"]["offset"] == 0
    assert meta["params"]["tok_emb"]["shape"] == [DEFAULT.vocab, DEFAULT.d_model]
    # offsets are contiguous and cover the whole vector
    spans = sorted(
        (v["offset"], v["offset"] + int(__import__("numpy").prod(v["shape"])))
        for v in meta["params"].values()
    )
    assert spans[0][0] == 0
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 == b0
    assert spans[-1][1] == meta["n_params"]


def test_mask_fixture_export(tmp_path):
    path = str(tmp_path / "masks.json")
    aot.export_mask_fixtures(TINY, path)
    import json

    cases = json.load(open(path))
    assert len(cases) >= 10
    for c in cases:
        assert sorted(c["sigma"]) == list(range(c["n"]))
        assert len(c["verify_h"]) == c["n"] * c["n"]
