"""L1 correctness: Pallas masked-attention kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, and mask patterns; assert_allclose against
ref.py as mandated by DESIGN.md §7.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

# Property sweeps need hypothesis; skip the whole module cleanly where it
# is not installed (offline containers) instead of erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import masked_attention, masked_attention_pallas
from compile.kernels.ref import masked_attention_ref

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    n=st.sampled_from([4, 8, 12, 16, 32]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    density=st.floats(0.1, 1.0),
)
def test_matches_ref_random_masks(b, h, n, dh, seed, density):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (b, h, n, dh), jnp.float32) for _ in range(3))
    mask = jnp.asarray((rng.random((b, n, n)) < density).astype(np.float32))
    out = masked_attention_pallas(q, k, v, mask)
    ref = masked_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([4, 8, 16, 32]),
    bk=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_invariance(n, bq, bk, seed):
    """Output must not depend on the chosen tiling."""
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (1, 2, n, 8), jnp.float32) for _ in range(3))
    mask = jnp.asarray((rng.random((1, n, n)) < 0.6).astype(np.float32))
    a = masked_attention_pallas(q, k, v, mask, block_q=bq, block_k=bk)
    b_ = masked_attention_pallas(q, k, v, mask, block_q=n, block_k=n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-6)


def test_causal_mask():
    rng = np.random.default_rng(0)
    n = 16
    q, k, v = (_rand(rng, (2, 2, n, 8), jnp.float32) for _ in range(3))
    causal = jnp.asarray(np.tril(np.ones((n, n), np.float32))[None].repeat(2, 0))
    out = masked_attention_pallas(q, k, v, causal)
    ref = masked_attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_fully_masked_rows_are_zero():
    """Rows that may attend to nothing must produce exact zeros (defined
    semantics for never-read rows), not NaNs."""
    rng = np.random.default_rng(1)
    n = 8
    q, k, v = (_rand(rng, (1, 1, n, 4), jnp.float32) for _ in range(3))
    mask = np.ones((1, n, n), np.float32)
    mask[0, 3, :] = 0.0
    mask[0, 6, :] = 0.0
    out = np.asarray(masked_attention_pallas(q, k, v, jnp.asarray(mask)))
    assert np.all(np.isfinite(out))
    np.testing.assert_array_equal(out[0, 0, 3], np.zeros(4, np.float32))
    np.testing.assert_array_equal(out[0, 0, 6], np.zeros(4, np.float32))


def test_bf16_close_to_f32():
    rng = np.random.default_rng(2)
    n = 16
    qf, kf, vf = (_rand(rng, (1, 2, n, 8), jnp.float32) for _ in range(3))
    mask = jnp.asarray((rng.random((1, n, n)) < 0.7).astype(np.float32))
    out16 = masked_attention_pallas(
        qf.astype(jnp.bfloat16), kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16), mask
    )
    ref = masked_attention_ref(qf, kf, vf, mask)
    np.testing.assert_allclose(
        np.asarray(out16, dtype=np.float32), np.asarray(ref), rtol=0.05, atol=0.05
    )


def test_gradients_flow_through_custom_vjp():
    rng = np.random.default_rng(3)
    n = 8
    q, k, v = (_rand(rng, (1, 1, n, 4), jnp.float32) for _ in range(3))
    mask = jnp.asarray((rng.random((1, n, n)) < 0.8).astype(np.float32))

    def f_pallas(q, k, v):
        return jnp.sum(masked_attention(q, k, v, mask) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(masked_attention_ref(q, k, v, mask) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
