"""Mask-builder invariants (python mirror of rust/src/model/mask.rs)."""

import numpy as np
import pytest

# Property sweeps need hypothesis; skip the whole module cleanly where it
# is not installed (offline containers) instead of erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import masks as M

SETTINGS = dict(max_examples=30, deadline=None)


def _case(seed, nmax=20):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, nmax))
    m = int(rng.integers(1, n))
    vis = sorted(rng.choice(n, size=m, replace=False).tolist())
    sigma = M.lattice_sigma(vis, n)
    return n, m, vis, sigma


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_lattice_sigma_is_bijection_and_sorted(seed):
    n, m, vis, sigma = _case(seed)
    assert sorted(sigma) == list(range(n))
    assert sigma[:m] == sorted(sigma[:m]) == vis
    assert sigma[m:] == sorted(sigma[m:])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_verify_mask_invariants(seed):
    n, m, vis, sigma = _case(seed)
    order = M.order_from_sigma(sigma)
    mh, mg = M.verify_masks(sigma, m)
    # 1. content stream sees itself, query stream at target rows does not
    assert np.all(np.diag(mh) == 1.0)
    for a in range(n):
        if order[a] >= m:
            assert mg[a, a] == 0.0
    # 2. prompt rows attend the full prompt and nothing else
    for a in vis:
        np.testing.assert_array_equal(
            mg[a], np.array([1.0 if order[b] < m else 0.0 for b in range(n)], np.float32)
        )
    # 3. target rows are strictly causal in order
    for a in range(n):
        if order[a] >= m:
            for b in range(n):
                want = 1.0 if (order[b] < m or order[b] < order[a]) else 0.0
                assert mg[a, b] == want
    # 4. h differs from g only on the diagonal
    off_diag = ~np.eye(n, dtype=bool)
    np.testing.assert_array_equal(mh[off_diag], mg[off_diag])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), extra=st.integers(0, 10))
def test_draft_mask_invariants(seed, extra):
    n, m, vis, sigma = _case(seed)
    n_known = min(n, m + extra)
    order = M.order_from_sigma(sigma)
    mh, mg = M.draft_masks(sigma, m, n_known)
    known = order < n_known
    # 1. nothing attends unknown positions (except content self-loop)
    for b in range(n):
        if not known[b]:
            col = mg[:, b]
            assert np.all(col == 0.0)
            assert np.all(np.delete(mh[:, b], b) == 0.0)
    # 2. unknown rows attend exactly the known set
    for a in range(n):
        if not known[a]:
            np.testing.assert_array_equal(mg[a], known.astype(np.float32))
    # 3. known rows equal the corresponding verify rows (Lemma 1 requirement)
    vh, vg = M.verify_masks(sigma, m)
    for a in range(n):
        if known[a]:
            # verify rows may attend later-known targets; draft restricts to
            # known, but for known rows order<order[a]<n_known so equal.
            np.testing.assert_array_equal(mg[a], vg[a])
            np.testing.assert_array_equal(mh[a], vh[a])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_draft_at_full_knowledge_equals_verify(seed):
    n, m, vis, sigma = _case(seed)
    dh, dg = M.draft_masks(sigma, m, n)
    vh, vg = M.verify_masks(sigma, m)
    np.testing.assert_array_equal(dh, vh)
    np.testing.assert_array_equal(dg, vg)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), extra=st.integers(0, 12))
def test_masks_from_order_matches_dense_builders(seed, extra):
    """The unified (order, m, known) constructor — the reference for the
    on-device construction in the compact fwd_ord artifacts — must equal
    the dense builders at every decode state, verify included."""
    n, m, vis, sigma = _case(seed)
    order = M.order_from_sigma(sigma)
    n_known = min(n, m + extra)
    h, g = M.masks_from_order(order, m, n_known)
    dh, dg = M.draft_masks(sigma, m, n_known)
    np.testing.assert_array_equal(h, dh)
    np.testing.assert_array_equal(g, dg)
    vh, vg = M.verify_masks(sigma, m)
    h_full, g_full = M.masks_from_order(order, m, n)
    np.testing.assert_array_equal(h_full, vh)
    np.testing.assert_array_equal(g_full, vg)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_masks_from_order_arbitrary_permutation(seed):
    """Non-lattice sigmas (Fig. 3 ablation path) go through the same
    unified constructor."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 16))
    m = int(rng.integers(1, n))
    sigma = rng.permutation(n).tolist()
    order = M.order_from_sigma(sigma)
    n_known = int(rng.integers(m, n + 1))
    h, g = M.masks_from_order(order, m, n_known)
    dh, dg = M.draft_masks(sigma, m, n_known)
    np.testing.assert_array_equal(h, dh)
    np.testing.assert_array_equal(g, dg)


# The committed-fixture parity gate lives in test_fixtures.py (NOT here):
# it must stay importable without hypothesis, which this module needs.
