"""L2 semantic tests: the properties ASSD's correctness rests on.

* chain rule: one-pass joint density (verify masks) == product of
  sequential conditionals (draft passes) — paper Eq. 2/9.
* Lemma 1 precondition: draft density at order n == verify density at
  order n given identical known tokens.
* pallas and reference forward paths agree.
* train_step reduces the loss on a learnable pattern.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.config import TINY
from compile.model import (
    adam_train_step,
    forward,
    forward_inc,
    forward_ord,
    init_params,
    loss_fn,
    masks_from_order_batched,
    prefill_inc,
)
from compile import masks as M

CFG = TINY


@pytest.fixture(scope="module")
def theta():
    return init_params(CFG, seed=3)


def _random_case(seed, m=None):
    rng = np.random.default_rng(seed)
    n = CFG.seq_len
    m = m or int(rng.integers(2, n // 2))
    toks = rng.integers(0, CFG.MASK, size=(1, n)).astype("int32")
    vis = sorted(rng.choice(n, size=m, replace=False).tolist())
    sigma = M.lattice_sigma(vis, n)
    return rng, n, m, toks, vis, sigma


def test_forward_shapes_finite(theta):
    _, n, m, toks, vis, sigma = _random_case(0)
    vh, vg = M.verify_masks(sigma, m)
    out = forward(CFG, theta, jnp.asarray(toks), jnp.asarray(vh[None]), jnp.asarray(vg[None]),
                  use_pallas=False)
    assert out.shape == (1, n, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_pallas_and_ref_forward_agree(theta):
    _, n, m, toks, vis, sigma = _random_case(1)
    vh, vg = M.verify_masks(sigma, m)
    args = (jnp.asarray(toks), jnp.asarray(vh[None]), jnp.asarray(vg[None]))
    a = forward(CFG, theta, *args, use_pallas=True)
    b = forward(CFG, theta, *args, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chain_rule_one_pass_joint_equals_sequential_product(theta, seed):
    rng, n, m, toks, vis, sigma = _random_case(seed)
    vh, vg = M.verify_masks(sigma, m)
    logits = forward(CFG, theta, jnp.asarray(toks), jnp.asarray(vh[None]), jnp.asarray(vg[None]),
                     use_pallas=False)
    logp = jax.nn.log_softmax(logits, -1)
    joint = sum(float(logp[0, sigma[i], toks[0, sigma[i]]]) for i in range(m, n))

    seq = np.full((1, n), CFG.MASK, dtype="int32")
    for p in vis:
        seq[0, p] = toks[0, p]
    total = 0.0
    for i in range(m, n):
        dh, dg = M.draft_masks(sigma, m, i)
        lg = forward(CFG, theta, jnp.asarray(seq), jnp.asarray(dh[None]), jnp.asarray(dg[None]),
                     use_pallas=False)
        lp = jax.nn.log_softmax(lg, -1)
        pos = sigma[i]
        total += float(lp[0, pos, toks[0, pos]])
        seq[0, pos] = toks[0, pos]
    np.testing.assert_allclose(joint, total, rtol=1e-4, atol=1e-4)


def test_lemma1_draft_density_equals_oracle_density(theta):
    rng, n, m, toks, vis, sigma = _random_case(5)
    n_known = m + 2
    vh, vg = M.verify_masks(sigma, m)
    dh, dg = M.draft_masks(sigma, m, n_known)
    draft_toks = np.array(toks, copy=True)
    for i in range(n_known, n):
        draft_toks[0, sigma[i]] = CFG.MASK
    lg_d = forward(CFG, theta, jnp.asarray(draft_toks), jnp.asarray(dh[None]),
                   jnp.asarray(dg[None]), use_pallas=False)
    lg_v = forward(CFG, theta, jnp.asarray(toks), jnp.asarray(vh[None]), jnp.asarray(vg[None]),
                   use_pallas=False)
    pos = sigma[n_known]
    d = np.asarray(jax.nn.log_softmax(lg_d, -1))[0, pos]
    v = np.asarray(jax.nn.log_softmax(lg_v, -1))[0, pos]
    np.testing.assert_allclose(d, v, rtol=1e-4, atol=1e-5)


def test_draft_logits_independent_of_unknown_content(theta):
    """Conditionally-independent drafting: logits at unknown positions must
    not change when OTHER unknown positions' contents change."""
    rng, n, m, toks, vis, sigma = _random_case(6)
    dh, dg = M.draft_masks(sigma, m, m)
    a = np.full((1, n), CFG.MASK, dtype="int32")
    b = np.full((1, n), CFG.MASK, dtype="int32")
    for p in vis:
        a[0, p] = toks[0, p]
        b[0, p] = toks[0, p]
    # scramble unknown contents in b
    for i in range(m, n):
        b[0, sigma[i]] = int(rng.integers(0, CFG.MASK))
    la = forward(CFG, theta, jnp.asarray(a), jnp.asarray(dh[None]), jnp.asarray(dg[None]),
                 use_pallas=False)
    lb = forward(CFG, theta, jnp.asarray(b), jnp.asarray(dh[None]), jnp.asarray(dg[None]),
                 use_pallas=False)
    for i in range(m, n):
        pos = sigma[i]
        np.testing.assert_allclose(
            np.asarray(la)[0, pos], np.asarray(lb)[0, pos], rtol=1e-5, atol=1e-5
        )


def test_masks_from_order_batched_matches_numpy_reference():
    """The jnp device-side constructor (lowered into fwd_ord artifacts)
    must agree with the numpy reference at every batched state."""
    rng = np.random.default_rng(21)
    n = TINY.seq_len
    b = 3
    orders, ms, knowns, want_h, want_g = [], [], [], [], []
    for _ in range(b):
        m = int(rng.integers(1, n))
        vis = sorted(rng.choice(n, size=m, replace=False).tolist())
        sigma = M.lattice_sigma(vis, n)
        order = M.order_from_sigma(sigma)
        known = int(rng.integers(m, n + 1))
        h, g = M.masks_from_order(order, m, known)
        orders.append(order)
        ms.append(m)
        knowns.append(known)
        want_h.append(h)
        want_g.append(g)
    bh, bg = masks_from_order_batched(
        jnp.asarray(np.stack(orders).astype("int32")),
        jnp.asarray(np.array(ms, "int32")),
        jnp.asarray(np.array(knowns, "int32")),
    )
    np.testing.assert_array_equal(np.asarray(bh), np.stack(want_h))
    np.testing.assert_array_equal(np.asarray(bg), np.stack(want_g))


def test_forward_ord_matches_dense_forward_plus_gather(theta):
    """The compact forward (device-side masks + row gather) must reproduce
    the dense path: forward under draft_masks, then take the same rows."""
    rng, n, m, toks, vis, sigma = _random_case(9)
    n_known = min(n, m + 3)
    order = M.order_from_sigma(sigma)
    want = np.array(
        [sigma[i] for i in range(n_known, min(n_known + 4, n))], dtype="int32"
    )[None]
    dh, dg = M.draft_masks(sigma, m, n_known)
    dense = forward(
        CFG, theta, jnp.asarray(toks), jnp.asarray(dh[None]), jnp.asarray(dg[None]),
        use_pallas=False,
    )
    gathered_dense = np.asarray(dense)[0, want[0]]
    compact = forward_ord(
        CFG,
        theta,
        jnp.asarray(toks),
        jnp.asarray(order.astype("int32")[None]),
        jnp.asarray(np.array([m], "int32")),
        jnp.asarray(np.array([n_known], "int32")),
        jnp.asarray(want),
        use_pallas=False,
    )
    assert compact.shape == (1, want.shape[1], CFG.vocab)
    np.testing.assert_allclose(
        np.asarray(compact)[0], gathered_dense, rtol=1e-5, atol=1e-5
    )


def test_incremental_forward_matches_compact_across_a_decode(theta):
    """Drive a full ASSD-shaped decode through the incremental path —
    prefill seeds the cache, every iteration appends last round's commits
    and computes only the active rows — and pin every step's logits to the
    compact path (forward_ord, itself pinned to dense-forward + gather),
    and the incrementally-grown cache to a from-scratch prefill at the
    same committed state."""
    rng = np.random.default_rng(31)
    n = CFG.seq_len
    m = 5
    vis = sorted(rng.choice(n, size=m, replace=False).tolist())
    sigma = M.lattice_sigma(vis, n)
    order = M.order_from_sigma(sigma).astype("int32")
    toks = np.full((1, n), CFG.MASK, dtype="int32")
    for p_ in vis:
        toks[0, p_] = int(rng.integers(0, CFG.MASK))

    def i32(x):
        return jnp.asarray(np.asarray(x, "int32"))

    def compact_rows(buf, known, want):
        out = forward_ord(
            CFG, theta, i32(buf), i32(order[None]), i32([m]), i32([known]),
            i32(np.array(want, "int32")[None]), use_pallas=False,
        )
        return np.asarray(out)[0]

    def inc_rows(buf, known, cached, rows, ck, cv):
        r = 8
        padded = list(rows) + [0] * (r - len(rows))
        logits, k_new, v_new = forward_inc(
            CFG, theta, i32(buf), i32(order[None]), i32([m]), i32([known]),
            i32([cached]), i32([len(rows)]), i32(np.array(padded, "int32")[None]),
            jnp.asarray(ck), jnp.asarray(cv),
        )
        return np.asarray(logits)[0], np.asarray(k_new)[0], np.asarray(v_new)[0]

    def prefill(buf, committed):
        ck, cv = prefill_inc(
            CFG, theta, i32(buf), i32(order[None]),
            i32(np.array(sigma, "int32")[None]), i32([m]), i32([committed]),
            use_pallas=False,
        )
        return np.asarray(ck).copy(), np.asarray(cv).copy()

    ck, cv = prefill(toks, m)
    assert np.all(ck[0, :, m:] == 0.0), "prefill must zero uncommitted slots"
    cached, c, w = m, m, 3
    while c < n:
        t = min(c + w, n)
        window = [sigma[i] for i in range(c, t)]
        appends = [sigma[j] for j in range(cached, c)]
        # draft-state call: appends first, then the window's want rows
        logits, k_new, v_new = inc_rows(toks, c, cached, appends + window, ck, cv)
        ref = compact_rows(toks, c, window)
        np.testing.assert_allclose(
            logits[len(appends):len(appends) + len(window)], ref,
            rtol=2e-4, atol=2e-4, err_msg=f"draft logits at c={c}",
        )
        for i in range(len(appends)):
            ck[0, :, cached + i] = k_new[:, i]
            cv[0, :, cached + i] = v_new[:, i]
        cached = c
        # the incrementally-grown cache equals a from-scratch prefill
        ck_ref, cv_ref = prefill(toks, cached)
        np.testing.assert_allclose(ck, ck_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(cv, cv_ref, rtol=2e-4, atol=2e-4)
        # fill drafts and run the verify-state call (known = n, no appends)
        drafted = toks.copy()
        for pos in window:
            drafted[0, pos] = int(rng.integers(0, CFG.MASK))
        logits, _, _ = inc_rows(drafted, n, cached, window, ck, cv)
        ref = compact_rows(drafted, n, window)
        np.testing.assert_allclose(
            logits[: len(window)], ref, rtol=2e-4, atol=2e-4,
            err_msg=f"verify logits at c={c}",
        )
        # commit an accepted prefix; the rest rolls back to MASK
        a = int(rng.integers(1, t - c + 1))
        for i in range(c, c + a):
            toks[0, sigma[i]] = drafted[0, sigma[i]]
        c += a


def test_train_step_reduces_loss(theta):
    rng = np.random.default_rng(11)
    n = CFG.seq_len
    b = 2
    # learnable pattern: alternating pair of tokens
    toks = np.tile(np.array([5, 9], dtype="int32"), n // 2)[None].repeat(b, 0)
    m = 2
    vis = [0, 1]
    sigma = M.lattice_sigma(vis, n)
    vh, vg = M.verify_masks(sigma, m)
    mask_h = jnp.asarray(np.tile(vh[None], (b, 1, 1)))
    mask_g = jnp.asarray(np.tile(vg[None], (b, 1, 1)))
    order = M.order_from_sigma(sigma)
    loss_w = jnp.asarray(np.tile((order >= m).astype("float32")[None], (b, 1)))
    t = theta
    mm = jnp.zeros_like(t)
    vv = jnp.zeros_like(t)
    losses = []
    for step in range(1, 41):
        t, mm, vv, loss = adam_train_step(
            CFG, t, mm, vv, jnp.float32(step), jnp.asarray(toks), mask_h, mask_g, loss_w,
            jnp.float32(1e-2), use_pallas=False,
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses
    assert np.isfinite(losses).all()


def test_loss_pallas_matches_ref(theta):
    _, n, m, toks, vis, sigma = _random_case(8)
    vh, vg = M.verify_masks(sigma, m)
    order = M.order_from_sigma(sigma)
    lw = jnp.asarray((order >= m).astype("float32")[None])
    args = (jnp.asarray(toks), jnp.asarray(vh[None]), jnp.asarray(vg[None]), lw)
    a = float(loss_fn(CFG, theta, *args, use_pallas=True))
    b = float(loss_fn(CFG, theta, *args, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-4)
