"""L1 correctness: fused streaming softmax-cross-entropy vs oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

# Property sweeps need hypothesis; skip the whole module cleanly where it
# is not installed (offline containers) instead of erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.xent import softmax_xent, softmax_xent_pallas
from compile.kernels.ref import softmax_xent_ref

SETTINGS = dict(max_examples=15, deadline=None)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    n=st.sampled_from([4, 8, 16]),
    v=st.sampled_from([8, 32, 64, 130]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 20.0),
)
def test_matches_ref(b, n, v, seed, scale):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray((scale * rng.normal(size=(b, n, v))).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    weights = jnp.asarray((rng.random((b, n)) < 0.5).astype(np.float32))
    got = float(softmax_xent_pallas(logits, targets, weights))
    want = float(softmax_xent_ref(logits, targets, weights))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    bv=st.sampled_from([8, 16, 32, 64]),
    br=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_invariance(bv, br, seed):
    rng = np.random.default_rng(seed)
    b, n, v = 2, 8, 64
    logits = jnp.asarray(rng.normal(size=(b, n, v)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    weights = jnp.asarray(np.ones((b, n), np.float32))
    a = float(softmax_xent_pallas(logits, targets, weights, block_r=br, block_v=bv))
    c = float(softmax_xent_pallas(logits, targets, weights, block_r=b * n, block_v=v))
    np.testing.assert_allclose(a, c, rtol=1e-6)


def test_all_weights_zero_is_zero_loss():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(1, 4, 16)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, 16, size=(1, 4)).astype(np.int32))
    weights = jnp.zeros((1, 4), jnp.float32)
    assert float(softmax_xent_pallas(logits, targets, weights)) == 0.0


def test_gradient_matches_ref():
    rng = np.random.default_rng(4)
    b, n, v = 2, 4, 32
    logits = jnp.asarray(rng.normal(size=(b, n, v)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    weights = jnp.asarray((rng.random((b, n)) < 0.7).astype(np.float32))
    gp = jax.grad(lambda l: softmax_xent(l, targets, weights))(logits)
    gr = jax.grad(lambda l: softmax_xent_ref(l, targets, weights))(logits)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-5, atol=1e-6)


def test_uniform_logits_loss_is_log_v():
    v = 64
    logits = jnp.zeros((1, 4, v), jnp.float32)
    targets = jnp.asarray(np.arange(4, dtype=np.int32)[None])
    weights = jnp.ones((1, 4), jnp.float32)
    got = float(softmax_xent_pallas(logits, targets, weights))
    np.testing.assert_allclose(got, np.log(v), rtol=1e-5)
