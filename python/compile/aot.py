"""AOT export: lower the AS-ARM to HLO text artifacts for the rust runtime.

Python runs exactly once (`make artifacts`); afterwards the rust binary is
self-contained. Interchange is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Exports into artifacts/:
  fwd_b{B}.hlo.txt        forward(theta, tokens, mask_h, mask_g) -> (logits,)
  fwd_ord_b{B}.hlo.txt    COMPACT forward(theta, tokens, order, m, known,
                          want[B,R]) -> (logits[B,R,V],): masks rebuilt on
                          device from (order, m, known), only the R
                          requested rows gathered back to the host
  fwd_inc_b{B}.hlo.txt    INCREMENTAL forward(theta, tokens, order, m,
                          known, cached, nrows, rows[B,R], cache_k, cache_v
                          [B,L,N,D]) -> (logits[B,R,V], k_new, v_new
                          [B,L,R,D]): only the R active rows are computed,
                          against the persistent per-lane K/V cache
  fwd_inc_pre_b1.hlo.txt  prefill(theta, tokens, order, sigma, m,
                          committed) -> (cache_k, cache_v [B,L,N,D]):
                          seeds a lane's cache (one h-stream pass)
  train_step_b{B}.hlo.txt adamw step -> (theta', m', v', loss)
  model_meta.json         dims + flat-theta layout (config.py) + ord_rows /
                          inc_rows (the gather / active-row widths the
                          compact and incremental families were lowered
                          with) + inc_cache (per-lane cache shape)
  params_init.bin         random-init flat theta, little-endian f32
  fixtures/masks.json     golden sigma->mask fixtures for rust parity tests
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import DEFAULT, ModelConfig
from .fixtures import export_mask_fixtures
from .model import (
    adam_train_step,
    forward,
    forward_inc,
    forward_ord,
    init_params,
    prefill_inc,
)

FWD_BATCH_SIZES = (1, 4)
TRAIN_BATCH_SIZES = (4,)
# Default row-gather width R of the compact fwd_ord family: covers every
# speculation window the scheduler admits (it clamps draft lengths to R via
# Engine::max_gather_rows); diffusion steps wanting more rows fall back to
# the dense path.
FWD_ORD_ROWS = 32
# Active-row width of the incremental fwd_inc family. An incremental step
# carries last iteration's committed rows (<= window) PLUS the current
# window's want rows (<= window), so 2x the compact gather width keeps the
# scheduler's window clamp unchanged when both families ship.
FWD_INC_ROWS = 64
# Prefill runs once per admitted sequence (the bidirectional prompt block
# cannot be appended in causal chunks), so batch 1 suffices.
INC_PREFILL_BATCH_SIZES = (1,)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_forward(cfg: ModelConfig, batch: int, use_pallas: bool = True) -> str:
    n, v = cfg.seq_len, cfg.vocab

    def fn(theta, tokens, mask_h, mask_g):
        return (forward(cfg, theta, tokens, mask_h, mask_g, use_pallas=use_pallas),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n, n), jnp.float32),
        jax.ShapeDtypeStruct((batch, n, n), jnp.float32),
    )
    return to_hlo_text(lowered)


def export_forward_ord(
    cfg: ModelConfig, batch: int, rows: int, use_pallas: bool = True
) -> str:
    """Lower the compact forward ABI: device-side mask construction from
    (order, m, known) + gather of the `rows` requested logit rows."""
    n = cfg.seq_len

    def fn(theta, tokens, order, m, known, want):
        return (
            forward_ord(
                cfg, theta, tokens, order, m, known, want, use_pallas=use_pallas
            ),
        )

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch, rows), jnp.int32),
    )
    return to_hlo_text(lowered)


def export_forward_inc(
    cfg: ModelConfig, batch: int, rows: int, use_pallas: bool = True
) -> str:
    """Lower the incremental forward: R active rows against the persistent
    per-layer K/V cache ([B, L, N, D], order-major)."""
    n, d, nl = cfg.seq_len, cfg.d_model, cfg.n_layers
    del use_pallas  # rectangular q-vs-kv attention uses the jnp reference

    def fn(theta, tokens, order, m, known, cached, nrows, rows_, cache_k, cache_v):
        return forward_inc(
            cfg, theta, tokens, order, m, known, cached, nrows, rows_, cache_k, cache_v
        )

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch, rows), jnp.int32),
        jax.ShapeDtypeStruct((batch, nl, n, d), jnp.float32),
        jax.ShapeDtypeStruct((batch, nl, n, d), jnp.float32),
    )
    return to_hlo_text(lowered)


def export_prefill_inc(cfg: ModelConfig, batch: int, use_pallas: bool = True) -> str:
    """Lower the incremental-path prefill: one content-stream pass that
    seeds a lane's K/V cache (order-major, zeroed beyond `committed`)."""
    n = cfg.seq_len

    def fn(theta, tokens, order, sigma, m, committed):
        return prefill_inc(
            cfg, theta, tokens, order, sigma, m, committed, use_pallas=use_pallas
        )

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return to_hlo_text(lowered)


def export_train_step(cfg: ModelConfig, batch: int, use_pallas: bool = True) -> str:
    n = cfg.seq_len
    p = cfg.n_params

    def fn(theta, m, v, step, tokens, mask_h, mask_g, loss_w, lr):
        return adam_train_step(
            cfg, theta, m, v, step, tokens, mask_h, mask_g, loss_w, lr, use_pallas=use_pallas
        )

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.int32),
        jax.ShapeDtypeStruct((batch, n, n), jnp.float32),
        jax.ShapeDtypeStruct((batch, n, n), jnp.float32),
        jax.ShapeDtypeStruct((batch, n), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower with the pure-jnp reference attention/xent instead of the Pallas kernels",
    )
    ap.add_argument(
        "--ord-rows",
        type=int,
        default=FWD_ORD_ROWS,
        help="row-gather width R of the compact fwd_ord_b{B} artifacts "
        "(recorded as ord_rows in model_meta.json)",
    )
    ap.add_argument(
        "--inc-rows",
        type=int,
        default=FWD_INC_ROWS,
        help="active-row width of the incremental fwd_inc_b{B} artifacts "
        "(recorded as inc_rows in model_meta.json)",
    )
    args = ap.parse_args()
    cfg = DEFAULT
    use_pallas = not args.no_pallas
    rows = min(args.ord_rows, cfg.seq_len)
    inc_rows = max(2, min(args.inc_rows, cfg.seq_len))
    os.makedirs(args.out_dir, exist_ok=True)
    os.makedirs(os.path.join(args.out_dir, "fixtures"), exist_ok=True)

    for b in FWD_BATCH_SIZES:
        text = export_forward(cfg, b, use_pallas)
        path = os.path.join(args.out_dir, f"fwd_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in FWD_BATCH_SIZES:
        text = export_forward_ord(cfg, b, rows, use_pallas)
        path = os.path.join(args.out_dir, f"fwd_ord_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in FWD_BATCH_SIZES:
        text = export_forward_inc(cfg, b, inc_rows, use_pallas)
        path = os.path.join(args.out_dir, f"fwd_inc_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in INC_PREFILL_BATCH_SIZES:
        text = export_prefill_inc(cfg, b, use_pallas)
        path = os.path.join(args.out_dir, f"fwd_inc_pre_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in TRAIN_BATCH_SIZES:
        text = export_train_step(cfg, b, use_pallas)
        path = os.path.join(args.out_dir, f"train_step_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(args.out_dir, "model_meta.json")
    meta = json.loads(cfg.meta_json())
    # Artifact-set property, not a model dimension: the gather width the
    # compact family above was lowered with (rust refuses to enable the
    # compact path without it).
    meta["ord_rows"] = rows
    # Same for the incremental family: the active-row width R and the
    # per-lane cache shape (order-major per-layer content-stream K/V).
    meta["inc_rows"] = inc_rows
    meta["inc_cache"] = {
        "layers": cfg.n_layers,
        "slots": cfg.seq_len,
        "d_model": cfg.d_model,
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")

    theta = np.asarray(init_params(cfg, args.seed), dtype="<f4")
    params_path = os.path.join(args.out_dir, "params_init.bin")
    theta.tofile(params_path)
    print(f"wrote {params_path} ({theta.size} f32)")

    fx_path = os.path.join(args.out_dir, "fixtures", "masks.json")
    export_mask_fixtures(cfg, fx_path)
    print(f"wrote {fx_path}")


if __name__ == "__main__":
    main()
