"""Layer-2: the AS-ARM — an XLNet-style two-stream attention transformer.

All functions are pure over a single flat parameter vector `theta` (layout
in config.py). Three entry points get AOT-lowered to HLO text by aot.py:

  forward(theta, tokens, mask_h, mask_g)            -> logits       (serving)
  train_step(theta, m, v, step, tokens, mask_h,
             mask_g, loss_w, lr)                    -> theta', m', v', loss

The two-stream design is the architectural contribution the paper leans on
(Sec. 4, Appendix C):

  * content stream h: input = tok_emb[x] + pos_emb. Carries token CONTENT;
    used only as keys/values (and to propagate content through layers).
  * query stream g: input = pos_emb + q_bias. Carries POSITION queries; its
    final hidden state produces the logits for every position, so a single
    forward pass yields p(x_sigma(i) | x_sigma(<i)) for ALL i simultaneously
    (one-pass joint density estimation, Fig. 1b) or the conditionally
    independent draft distributions (Fig. 1a), depending only on the masks.
  * weights are shared between streams (XLNet); only inputs + masks differ.

The masks mask_h/mask_g are INPUTS: Layer 3 (rust) builds them from sigma /
the visible set, which is exactly the paper's "the architecture is the same,
the way we query it is different".
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.attention import masked_attention
from .kernels.ref import masked_attention_ref, softmax_xent_ref
from .kernels.xent import softmax_xent


def unpack(cfg: ModelConfig, theta: jax.Array) -> Dict[str, jax.Array]:
    """Slice the flat theta vector into named parameter arrays (static)."""
    out = {}
    for name, (off, shape) in cfg.param_offsets().items():
        size = 1
        for s in shape:
            size *= s
        out[name] = theta[off : off + size].reshape(shape)
    return out


def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _heads(x, n_heads):  # [B,N,D] -> [B,H,N,Dh]
    b, n, d = x.shape
    return x.reshape(b, n, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _unheads(x):  # [B,H,N,Dh] -> [B,N,D]
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def forward(
    cfg: ModelConfig,
    theta: jax.Array,
    tokens: jax.Array,  # [B, N] int32
    mask_h: jax.Array,  # [B, N, N] content-stream mask (may include self)
    mask_g: jax.Array,  # [B, N, N] query-stream mask (strictly precedes)
    *,
    use_pallas: bool = True,
) -> jax.Array:
    """Two-stream forward; returns logits [B, N, V] from the query stream."""
    p = unpack(cfg, theta)
    attn = masked_attention if use_pallas else masked_attention_ref
    b, n = tokens.shape

    h = p["tok_emb"][tokens] + p["pos_emb"][None, :n, :]
    g = jnp.broadcast_to(p["pos_emb"][None, :n, :] + p["q_bias"], h.shape)

    for l in range(cfg.n_layers):
        # --- two-stream attention (shared projections) ---
        hn = _layer_norm(h, p["ln1_s"][l], p["ln1_b"][l])
        gn = _layer_norm(g, p["ln1_s"][l], p["ln1_b"][l])
        k = _heads(hn @ p["wk"][l], cfg.n_heads)
        v = _heads(hn @ p["wv"][l], cfg.n_heads)
        qh = _heads(hn @ p["wq"][l], cfg.n_heads)
        qg = _heads(gn @ p["wq"][l], cfg.n_heads)
        ah = _unheads(attn(qh, k, v, mask_h)) @ p["wo"][l]
        ag = _unheads(attn(qg, k, v, mask_g)) @ p["wo"][l]
        h = h + ah
        g = g + ag
        # --- MLP (shared) ---
        hn2 = _layer_norm(h, p["ln2_s"][l], p["ln2_b"][l])
        gn2 = _layer_norm(g, p["ln2_s"][l], p["ln2_b"][l])
        h = h + jax.nn.gelu(hn2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
        g = g + jax.nn.gelu(gn2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]

    gf = _layer_norm(g, p["lnf_s"], p["lnf_b"])
    # Output projection tied to the token embedding.
    logits = gf @ p["tok_emb"].T + p["out_b"]
    return logits


def _g_allows(oa: jax.Array, ob: jax.Array, m: jax.Array, known: jax.Array) -> jax.Array:
    """The scalar mask predicate (jnp twin of rust ``mask::g_allows``),
    broadcast over any compatible shapes: may the query-stream row with
    order ``oa`` attend the column with order ``ob`` at decode state
    ``known``? All construction paths — the dense builders, the compact
    on-device masks, and the incremental path's column masks — are
    projections of this one predicate."""
    prompt_col = ob < m
    return jnp.where(
        oa < m,
        prompt_col,
        jnp.where(oa < known, prompt_col | ((ob < known) & (ob < oa)), ob < known),
    )


def masks_from_order_batched(
    order: jax.Array,  # [B, N] int32, position -> order index
    m: jax.Array,  # [B] int32, prompt sizes
    known: jax.Array,  # [B] int32, decode states (known == N => verify)
) -> Tuple[jax.Array, jax.Array]:
    """DEVICE-SIDE mask construction: the jnp twin of
    masks.masks_from_order, batched, lowered into the compact
    ``fwd_ord_b{B}`` artifacts so the O(N^2) masks never cross the host
    boundary. Returns ([B,N,N] mask_h, [B,N,N] mask_g), f32."""
    oa = order[:, :, None]
    ob = order[:, None, :]
    mm = m[:, None, None]
    kk = known[:, None, None]
    g = _g_allows(oa, ob, mm, kk).astype(jnp.float32)
    n = order.shape[1]
    h = jnp.maximum(g, jnp.eye(n, dtype=jnp.float32)[None, :, :])
    return h, g


def forward_ord(
    cfg: ModelConfig,
    theta: jax.Array,
    tokens: jax.Array,  # [B, N] int32
    order: jax.Array,  # [B, N] int32
    m: jax.Array,  # [B] int32
    known: jax.Array,  # [B] int32
    want: jax.Array,  # [B, R] int32 — positions whose logit rows to return
    *,
    use_pallas: bool = True,
) -> jax.Array:
    """Compact forward ABI: reconstruct (mask_h, mask_g) on device from
    (order, m, known), run the two-stream forward, and gather only the
    requested R rows before anything returns to the host. [B, R, V]."""
    mask_h, mask_g = masks_from_order_batched(order, m, known)
    logits = forward(cfg, theta, tokens, mask_h, mask_g, use_pallas=use_pallas)
    return jnp.take_along_axis(logits, want[:, :, None], axis=1)


def prefill_inc(
    cfg: ModelConfig,
    theta: jax.Array,
    tokens: jax.Array,  # [B, N] int32
    order: jax.Array,  # [B, N] int32, position -> order index
    sigma: jax.Array,  # [B, N] int32, order index -> position
    m: jax.Array,  # [B] int32
    committed: jax.Array,  # [B] int32 — orders < committed hold final tokens
    *,
    use_pallas: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Incremental-path prefill: one full content-stream (h) pass that
    seeds a sequence's per-layer K/V cache.

    The prompt block attends bidirectionally (every prompt row sees every
    prompt column), so prompt rows cannot be appended to the cache in
    causal chunks — they must all be computed together, once. This lowers
    as ``fwd_inc_pre_b{B}.hlo.txt``: it runs the h stream only (no query
    stream, no logits) under the verify-family masks, then gathers the
    per-layer K/V rows into ORDER-major cache layout (slot j holds the
    K/V of position sigma[j]) and zeroes slots >= committed.

    Returns (cache_k, cache_v), each [B, L, N, D] f32.
    """
    p = unpack(cfg, theta)
    attn = masked_attention if use_pallas else masked_attention_ref
    b, n = tokens.shape
    oa = order[:, :, None]
    ob = order[:, None, :]
    mm = m[:, None, None]
    # Committed rows' attention set is state-independent (a known row
    # attends prompt + strictly-earlier-in-order; this is what makes the
    # cache valid forever), so the full-knowledge masks are correct for
    # every slot the output keeps. Rows >= committed are computed too but
    # zeroed below — nothing committed ever attends them.
    g_full = _g_allows(oa, ob, mm, jnp.full_like(mm, n)).astype(jnp.float32)
    mask_h = jnp.maximum(g_full, jnp.eye(n, dtype=jnp.float32)[None, :, :])
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :n, :]
    ks, vs = [], []
    for l in range(cfg.n_layers):
        hn = _layer_norm(h, p["ln1_s"][l], p["ln1_b"][l])
        k = hn @ p["wk"][l]
        v = hn @ p["wv"][l]
        ks.append(k)
        vs.append(v)
        qh = _heads(hn @ p["wq"][l], cfg.n_heads)
        ah = _unheads(attn(qh, _heads(k, cfg.n_heads), _heads(v, cfg.n_heads), mask_h))
        h = h + ah @ p["wo"][l]
        hn2 = _layer_norm(h, p["ln2_s"][l], p["ln2_b"][l])
        h = h + jax.nn.gelu(hn2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
    k_pos = jnp.stack(ks, axis=1)  # [B, L, N, D], position-major
    v_pos = jnp.stack(vs, axis=1)
    idx = sigma[:, None, :, None]  # order-major gather: slot j <- sigma[j]
    live = (jnp.arange(n)[None, :] < committed[:, None]).astype(jnp.float32)
    live = live[:, None, :, None]
    cache_k = jnp.take_along_axis(k_pos, idx, axis=2) * live
    cache_v = jnp.take_along_axis(v_pos, idx, axis=2) * live
    return cache_k, cache_v


def forward_inc(
    cfg: ModelConfig,
    theta: jax.Array,
    tokens: jax.Array,  # [B, N] int32 — full buffer (active-row embeddings)
    order: jax.Array,  # [B, N] int32
    m: jax.Array,  # [B] int32
    known: jax.Array,  # [B] int32 — decode state for the query-stream rows
    cached: jax.Array,  # [B] int32 — cache slots < cached are live
    nrows: jax.Array,  # [B] int32 — real entries of `rows`
    rows: jax.Array,  # [B, R] int32 — active positions: newly-committed
    #   rows to append (first entries, orders cached..) then the window/
    #   want rows; padded with position 0 beyond nrows
    cache_k: jax.Array,  # [B, L, N, D] f32, ORDER-major (slot j = order j)
    cache_v: jax.Array,  # [B, L, N, D] f32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Incremental forward: compute ONLY the R active rows, attending the
    persistent per-layer content-stream K/V cache plus the active rows
    themselves. Per iteration this is O(R·(C+R)·D) attention instead of
    the full O(N²·D) — the compute half of the compact-ABI story (which
    removed the O(N²) *traffic*; see docs/ARCHITECTURE.md §Incremental
    forward & KV cache).

    Masks are per-column evaluations of the same ``_g_allows`` predicate
    as every other path: active h rows use the causal committed predicate
    (prompt | earlier-in-order | self) — exact for appended committed rows
    and for verify-state windows, and harmless for draft-state windows,
    whose columns nothing known ever attends; g rows use the
    (m, known)-state predicate over cache and active columns.

    Attention here is the pure-jnp reference path (rectangular q-vs-kv
    shapes; the Pallas kernel tiles square [N, N] blocks), which the
    kernel itself is pinned allclose to.

    Returns (logits [B, R, V], k_new [B, L, R, D], v_new [B, L, R, D]):
    logits for every active row (the caller slices its want rows), and
    the per-layer K/V of every active row (the caller appends only the
    committed prefix of them to its cache).
    """
    p = unpack(cfg, theta)
    b, n = tokens.shape
    r = rows.shape[1]
    f32 = jnp.float32
    row_tok = jnp.take_along_axis(tokens, rows, axis=1)  # [B, R]
    row_ord = jnp.take_along_axis(order, rows, axis=1)  # [B, R]
    pos_e = p["pos_emb"][rows]  # [B, R, D]
    h = p["tok_emb"][row_tok] + pos_e
    g = pos_e + p["q_bias"]
    real = jnp.arange(r)[None, :] < nrows[:, None]  # [B, R]
    oa = row_ord[:, :, None]  # [B, R, 1] query orders
    mm = m[:, None, None]
    kk = known[:, None, None]
    cc = cached[:, None, None]
    # cache columns: slot j holds the committed row with order j
    j = jnp.arange(n)[None, None, :]  # [1, 1, N]
    live = j < cc
    h_cache = (live & ((j < mm) | (j < oa))).astype(f32)  # [B, R, N]
    g_cache = (live & _g_allows(oa, j, mm, kk)).astype(f32)
    # active columns: column r2 is active row r2 (order row_ord[r2])
    ob = row_ord[:, None, :]  # [B, 1, R]
    col_real = real[:, None, :]
    eye = jnp.eye(r, dtype=bool)[None, :, :]
    h_act = ((col_real & ((ob < mm) | (ob < oa))) | eye).astype(f32)  # [B, R, R]
    g_act = (col_real & _g_allows(oa, ob, mm, kk)).astype(f32)
    mask_h = jnp.concatenate([h_cache, h_act], axis=2)  # [B, R, N+R]
    mask_g = jnp.concatenate([g_cache, g_act], axis=2)
    nh = cfg.n_heads
    ks, vs = [], []
    for l in range(cfg.n_layers):
        hn = _layer_norm(h, p["ln1_s"][l], p["ln1_b"][l])
        gn = _layer_norm(g, p["ln1_s"][l], p["ln1_b"][l])
        k_act = hn @ p["wk"][l]  # [B, R, D]
        v_act = hn @ p["wv"][l]
        ks.append(k_act)
        vs.append(v_act)
        k_cols = _heads(jnp.concatenate([cache_k[:, l], k_act], axis=1), nh)
        v_cols = _heads(jnp.concatenate([cache_v[:, l], v_act], axis=1), nh)
        qh = _heads(hn @ p["wq"][l], nh)
        qg = _heads(gn @ p["wq"][l], nh)
        ah = _unheads(masked_attention_ref(qh, k_cols, v_cols, mask_h))
        ag = _unheads(masked_attention_ref(qg, k_cols, v_cols, mask_g))
        h = h + ah @ p["wo"][l]
        g = g + ag @ p["wo"][l]
        hn2 = _layer_norm(h, p["ln2_s"][l], p["ln2_b"][l])
        gn2 = _layer_norm(g, p["ln2_s"][l], p["ln2_b"][l])
        h = h + jax.nn.gelu(hn2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
        g = g + jax.nn.gelu(gn2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
    gf = _layer_norm(g, p["lnf_s"], p["lnf_b"])
    logits = gf @ p["tok_emb"].T + p["out_b"]
    k_new = jnp.stack(ks, axis=1)  # [B, L, R, D]
    v_new = jnp.stack(vs, axis=1)
    return logits, k_new, v_new


def loss_fn(
    cfg: ModelConfig,
    theta: jax.Array,
    tokens: jax.Array,
    mask_h: jax.Array,
    mask_g: jax.Array,
    loss_w: jax.Array,  # [B, N] 1.0 at positions whose density is being taught
    *,
    use_pallas: bool = True,
) -> jax.Array:
    """Teacher-forced joint conditional loss (paper Eq. 7).

    With verify-mode masks built from (m, sigma), the summed per-position
    NLLs factor exactly into log p(x_sigma(>=m) | x_sigma(<m)) — Eq. 9.
    """
    logits = forward(cfg, theta, tokens, mask_h, mask_g, use_pallas=use_pallas)
    xent = softmax_xent if use_pallas else softmax_xent_ref
    return xent(logits, tokens, loss_w)


def adam_train_step(
    cfg: ModelConfig,
    theta: jax.Array,
    m: jax.Array,
    v: jax.Array,
    step: jax.Array,  # f32 scalar, 1-based
    tokens: jax.Array,
    mask_h: jax.Array,
    mask_g: jax.Array,
    loss_w: jax.Array,
    lr: jax.Array,  # f32 scalar
    *,
    use_pallas: bool = True,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    clip: float = 1.0,
    weight_decay: float = 0.01,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One AdamW step on the flat theta; returns (theta', m', v', loss)."""
    loss, grad = jax.value_and_grad(
        lambda t: loss_fn(cfg, t, tokens, mask_h, mask_g, loss_w, use_pallas=use_pallas)
    )(theta)
    # Global-norm clip.
    gnorm = jnp.sqrt(jnp.sum(jnp.square(grad)))
    grad = grad * jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
    m = beta1 * m + (1.0 - beta1) * grad
    v = beta2 * v + (1.0 - beta2) * jnp.square(grad)
    mhat = m / (1.0 - beta1**step)
    vhat = v / (1.0 - beta2**step)
    update = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * theta
    theta = theta - lr * update
    return theta, m, v, loss


def init_params(cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Random init of the flat theta (scaled-normal fan-in init)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in cfg.param_spec():
        key, sub = jax.random.split(key)
        if name.endswith("_s"):  # layer-norm scales
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith("_b") or name == "q_bias":
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        elif name in ("tok_emb", "pos_emb"):
            parts.append(0.02 * jax.random.normal(sub, shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in**-0.5
            parts.append(std * jax.random.normal(sub, shape, jnp.float32).reshape(-1))
    return jnp.concatenate(parts)


def jit_forward(cfg: ModelConfig, use_pallas: bool = True):
    return jax.jit(functools.partial(forward, cfg, use_pallas=use_pallas))


def jit_train_step(cfg: ModelConfig, use_pallas: bool = True):
    return jax.jit(functools.partial(adam_train_step, cfg, use_pallas=use_pallas))
