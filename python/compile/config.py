"""Model configuration and flat-parameter layout for the AS-ARM.

The whole parameter tree is packed into ONE flat f32 vector `theta` so that
the rust side (Layer 3) only ever handles a single contiguous buffer for
checkpointing and PJRT execution. Offsets are computed here, used by
`model.py` to unpack, and exported to `artifacts/model_meta.json` so rust can
introspect the layout (e.g. for parameter-count reporting).

Architecture: XLNet-style two-stream attention transformer (the AS-ARM of
the paper). Weights are SHARED between the content stream (h) and the query
stream (g) exactly as in XLNet; the two streams differ only in their inputs
(h: token+position embedding, g: position embedding + learned query bias)
and their attention masks (h: may see self; g: strictly preceding order
indices only — paper Eq. 6 / Appendix C).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the AS-ARM transformer."""

    vocab: int = 258  # 256 bytes + MASK(256) + PAD(257)
    seq_len: int = 128
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512

    # Token ids for the specials (mirrored in rust/src/tokenizer).
    MASK: int = 256
    PAD: int = 257

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list defining the flat theta layout."""
        V, N, D, L, F = (
            self.vocab,
            self.seq_len,
            self.d_model,
            self.n_layers,
            self.d_ff,
        )
        return [
            ("tok_emb", (V, D)),
            ("pos_emb", (N, D)),
            ("q_bias", (D,)),  # learned query-stream seed (XLNet's w vector)
            # Attention projections, stacked over layers, shared by streams.
            ("wq", (L, D, D)),
            ("wk", (L, D, D)),
            ("wv", (L, D, D)),
            ("wo", (L, D, D)),
            # Pre-LN layer norms.
            ("ln1_s", (L, D)),
            ("ln1_b", (L, D)),
            ("ln2_s", (L, D)),
            ("ln2_b", (L, D)),
            # MLP.
            ("w1", (L, D, F)),
            ("b1", (L, F)),
            ("w2", (L, F, D)),
            ("b2", (L, D)),
            # Final norm + output bias (output projection is tied to tok_emb).
            ("lnf_s", (D,)),
            ("lnf_b", (D,)),
            ("out_b", (V,)),
        ]

    def param_offsets(self) -> Dict[str, Tuple[int, Tuple[int, ...]]]:
        """name -> (flat offset, shape)."""
        out: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
        off = 0
        for name, shape in self.param_spec():
            size = 1
            for s in shape:
                size *= s
            out[name] = (off, shape)
            off += size
        return out

    @property
    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_spec():
            size = 1
            for s in shape:
                size *= s
            total += size
        return total

    def meta_json(self) -> str:
        """Serialize the layout for the rust side."""
        offs = self.param_offsets()
        return json.dumps(
            {
                "vocab": self.vocab,
                "seq_len": self.seq_len,
                "d_model": self.d_model,
                "n_layers": self.n_layers,
                "n_heads": self.n_heads,
                "d_ff": self.d_ff,
                "mask_id": self.MASK,
                "pad_id": self.PAD,
                "n_params": self.n_params,
                "params": {
                    name: {"offset": off, "shape": list(shape)}
                    for name, (off, shape) in offs.items()
                },
            },
            indent=1,
        )


# The default config used for every artifact this repo ships.
DEFAULT = ModelConfig()

# A tiny config for fast unit tests.
TINY = ModelConfig(vocab=32, seq_len=16, d_model=16, n_layers=2, n_heads=2, d_ff=32, MASK=30, PAD=31)
