"""Attention-mask construction from (sigma, m, n) — python mirror.

The AUTHORITATIVE implementation lives in rust (rust/src/model/mask.rs):
masks are built on the request path by Layer 3. This python mirror exists
for (a) L2 tests (chain-rule density consistency needs real masks) and
(b) golden cross-language parity fixtures consumed by `cargo test`.

State of a generation (paper Sec. 2.4 / Alg. 1 notation):

  * sigma: order -> position bijection. Under the binary-lattice protocol
    (Eq. 4) sigma = sorted(prompt positions) ++ sorted(target positions).
  * m: number of prompt tokens (order indices < m are the prompt).
  * n: number of KNOWN tokens (prompt + already-accepted targets), m <= n.

Mask semantics (Eq. 6 + Appendix C), with order[pos] = sigma^-1(pos):

  verify (Fig. 1b, density estimation; depends on sigma and m only):
    prompt rows attend the full prompt (we never evaluate its density);
    target rows attend the prompt plus strictly-earlier targets;
    the content stream additionally sees itself.

  draft (Fig. 1a, parallel sampling; depends on sigma, m and n):
    identical to verify for all KNOWN rows — this is what makes Lemma 1
    hold exactly: the content representations of known tokens are
    bit-for-bit the same computation in the draft pass and the verify
    pass, so the draft density of the first speculated token equals the
    oracle density and it is always accepted;
    UNKNOWN query rows attend exactly the known set (order < n), giving
    the conditionally-independent draft p(. | x_sigma(<n));
    nothing ever attends to an unknown position (they hold MASK tokens).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def order_from_sigma(sigma: Sequence[int]) -> np.ndarray:
    """sigma maps order->position; returns position->order."""
    n = len(sigma)
    order = np.zeros(n, dtype=np.int64)
    for i, pos in enumerate(sigma):
        order[pos] = i
    return order


def lattice_sigma(visible: Sequence[int], n: int) -> List[int]:
    """Binary-lattice sigma (Eq. 4): sorted prompt, then sorted targets."""
    vis = sorted(visible)
    vis_set = set(vis)
    tgt = [p for p in range(n) if p not in vis_set]
    return vis + tgt


def verify_masks(sigma: Sequence[int], m: int) -> Tuple[np.ndarray, np.ndarray]:
    """Density-estimation masks (Fig. 1b). Returns (mask_h, mask_g), [N,N] f32."""
    n = len(sigma)
    order = order_from_sigma(sigma)
    is_prompt = order < m
    mask_g = np.zeros((n, n), dtype=np.float32)
    for a in range(n):
        for b in range(n):
            if is_prompt[a]:
                mask_g[a, b] = 1.0 if is_prompt[b] else 0.0
            else:
                if is_prompt[b] or order[b] < order[a]:
                    mask_g[a, b] = 1.0
    mask_h = mask_g.copy()
    for a in range(n):
        mask_h[a, a] = 1.0
    return mask_h, mask_g


def masks_from_order(order: np.ndarray, m: int, known: int) -> Tuple[np.ndarray, np.ndarray]:
    """Unified (order, m, known) mask constructor — the numpy REFERENCE for
    the on-device construction baked into the compact ``fwd_ord_b{B}``
    artifacts (model.py::masks_from_order_batched is the jnp twin that gets
    lowered into the HLO).

    ``known == n`` reproduces ``verify_masks``; ``m <= known < n`` the
    draft masks at decode state ``known`` — one parameterization covers
    both families because ``draft_masks(sigma, m, n) == verify_masks``.
    Mirrors rust's ``model::mask::g_allows`` predicate exactly.
    """
    order = np.asarray(order, dtype=np.int64)
    oa = order[:, None]
    ob = order[None, :]
    prompt_col = ob < m
    g = np.where(
        oa < m,
        prompt_col,
        np.where(oa < known, prompt_col | ((ob < known) & (ob < oa)), ob < known),
    ).astype(np.float32)
    h = g.copy()
    np.fill_diagonal(h, 1.0)
    return h, g


def draft_masks(sigma: Sequence[int], m: int, n_known: int) -> Tuple[np.ndarray, np.ndarray]:
    """Parallel-sampling masks (Fig. 1a) at decode state n. [N,N] f32 each."""
    n = len(sigma)
    order = order_from_sigma(sigma)
    is_prompt = order < m
    known = order < n_known
    mask_g = np.zeros((n, n), dtype=np.float32)
    for a in range(n):
        for b in range(n):
            if known[a]:
                # Known rows: identical to verify (Lemma 1's requirement).
                if is_prompt[a]:
                    mask_g[a, b] = 1.0 if is_prompt[b] else 0.0
                else:
                    if is_prompt[b] or (known[b] and order[b] < order[a]):
                        mask_g[a, b] = 1.0
            else:
                # Unknown rows: attend exactly the known set.
                mask_g[a, b] = 1.0 if known[b] else 0.0
    mask_h = mask_g.copy()
    for a in range(n):
        mask_h[a, a] = 1.0
    return mask_h, mask_g
