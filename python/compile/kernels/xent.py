"""Layer-1 Pallas kernel: fused streaming softmax-cross-entropy.

Training-loss hot spot. Computing the teacher-forced joint loss (paper
Eq. 7) naively materializes softmax probabilities over [B, N, V]; this
kernel instead streams the vocab dimension in VMEM-sized tiles and keeps
only a running (max, sum-exp, target-logit) triple per row — the classic
online-logsumexp trick, fused with the target-gather.

Like kernels/attention.py this is forward-only Pallas (interpret=True for
CPU PJRT); `softmax_xent` wraps it in a custom_vjp whose backward pass is
the analytic gradient (softmax(logits) - onehot(target)) * w / denom,
expressed in jnp. The forward value is bit-compatible with the pure-jnp
oracle in kernels/ref.py up to float tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _xent_kernel(logits_ref, tgt_ref, w_ref, nll_ref, *, block_v: int, n_v: int, vocab: int):
    """One grid step: a tile of rows, streaming over vocab tiles.

    logits tile: [R, V]; targets/weights: [R]. Output: weighted nll [R].
    """
    rows = logits_ref.shape[0]
    tgt = tgt_ref[...]  # [R] int32
    w = w_ref[...]  # [R] f32

    def body(i, carry):
        m_prev, l_prev, t_prev = carry
        start = i * block_v
        lg = logits_ref[:, pl.dslice(start, block_v)].astype(jnp.float32)  # [R, BV]
        m_cur = jnp.max(lg, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(lg - m_new[:, None]), -1)
        # Gather the target logit if it falls in this tile.
        cols = start + jax.lax.iota(jnp.int32, block_v)[None, :]  # [1, BV]
        hit = (cols == tgt[:, None]).astype(jnp.float32)  # [R, BV]
        t_new = t_prev + jnp.sum(lg * hit, axis=-1)
        return m_new, l_new, t_new

    m0 = jnp.full((rows,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rows,), jnp.float32)
    t0 = jnp.zeros((rows,), jnp.float32)
    m, l, t = jax.lax.fori_loop(0, n_v, body, (m0, l0, t0))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    nll_ref[...] = ((lse - t) * w).astype(nll_ref.dtype)


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_r", "block_v"))
def softmax_xent_pallas(logits, targets, weights, block_r: int = 32, block_v: int = 128):
    """Pallas forward for the weighted mean NLL.

    Shapes: logits [B,N,V], targets [B,N] int32, weights [B,N] f32.
    Returns a scalar f32.
    """
    b, n, v = logits.shape
    rows = b * n
    br = _pick_block(rows, block_r)
    bv = _pick_block(v, block_v)
    lg = logits.reshape(rows, v)
    tg = targets.reshape(rows).astype(jnp.int32)
    wt = weights.reshape(rows).astype(jnp.float32)

    kernel = functools.partial(_xent_kernel, block_v=bv, n_v=v // bv, vocab=v)
    nll = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, v), lambda r: (r, 0)),
            pl.BlockSpec((br,), lambda r: (r,)),
            pl.BlockSpec((br,), lambda r: (r,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(lg, tg, wt)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    return jnp.sum(nll) / denom


@jax.custom_vjp
def softmax_xent(logits, targets, weights):
    """Weighted mean softmax cross-entropy with fused Pallas forward."""
    return softmax_xent_pallas(logits, targets, weights)


def _fwd(logits, targets, weights):
    return softmax_xent_pallas(logits, targets, weights), (logits, targets, weights)


def _bwd(res, g):
    logits, targets, weights = res
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    dlogits = (p - onehot) * weights[..., None] / denom * g
    return dlogits.astype(logits.dtype), None, None


softmax_xent.defvjp(_fwd, _bwd)
