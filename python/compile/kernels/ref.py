"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel must be
allclose to its oracle over the hypothesis shape/dtype/mask sweeps in
python/tests/. They are also used as the backward pass of the custom-vjp
wrappers (the Pallas kernels are forward-only; gradients are taken through
these mathematically identical functions — see kernels/attention.py).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def masked_attention_ref(q, k, v, mask):
    """Masked multi-head attention, reference implementation.

    Args:
      q: [B, H, N, Dh] queries (one stream).
      k: [B, H, N, Dh] keys (content stream).
      v: [B, H, N, Dh] values (content stream).
      mask: [B, N, N] 1.0 = query row may attend to key col, 0.0 = may not.

    Returns:
      [B, H, N, Dh] attention outputs. Rows whose mask is all-zero return 0
      (softmax over an empty set is defined as the zero vector here; such
      rows are never read by the model because their logits are discarded).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    bias = (1.0 - mask[:, None, :, :]) * NEG_INF
    logits = logits + bias.astype(logits.dtype)
    # Numerically stable softmax that yields exact zeros for fully-masked rows.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask[:, None, :, :] > 0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def softmax_xent_ref(logits, targets, weights):
    """Weighted softmax cross-entropy, reference implementation.

    Args:
      logits: [B, N, V].
      targets: [B, N] int32 target token ids.
      weights: [B, N] per-position loss weights (0 for non-target positions).

    Returns:
      Scalar: sum_i w_i * (-log p(target_i)) / max(sum_i w_i, 1).
    """
    mx = jnp.max(logits, -1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), -1)) + mx[..., 0]
    tgt = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - tgt
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.sum(nll * weights) / denom
