"""Layer-1 Pallas kernel: tiled masked two-stream attention.

This is the paper's compute hot spot (attention with the arbitrary Eq.-6
masks of an AS-ARM; FlashAttention for this setting is listed by the paper
as the key extension). The kernel is a flash-attention-style online-softmax
over K/V column tiles, with the *arbitrary* per-(batch, row, col) mask
streamed tile-by-tile alongside K/V.

TPU adaptation (DESIGN.md §6): instead of porting GPU threadblock tiling we
tile for VMEM — each grid step holds one (BLOCK_Q × Dh) query tile, one
(BLOCK_K × Dh) K and V tile, and one (BLOCK_Q × BLOCK_K) mask tile in VMEM,
with 8×128-multiple shapes to keep MXU-systolic-friendly operand tiles. The
HBM↔VMEM schedule that a GPU kernel would express with threadblocks +
shared-memory staging is expressed here with the BlockSpec index maps.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO (loops + dynamic slices). Real-TPU efficiency is estimated in
EXPERIMENTS.md §Perf from the VMEM footprint + MXU utilization of these
block shapes.

Gradients: the kernel is forward-only. `masked_attention` wraps it in a
custom_vjp whose backward pass differentiates the mathematically identical
pure-jnp oracle (kernels/ref.py), so the SAME function is used in the
serving graph (fwd) and the training graph (fwd + exact bwd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import masked_attention_ref

NEG_INF = -1e9


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k: int, n_kv: int, scale: float):
    """One grid step: queries tile (1, BQ, Dh) against all KV tiles.

    Grid is (B*H, N // BLOCK_Q). K/V/mask come in as full rows for this
    batch-head / query tile; the kernel streams them in BLOCK_K chunks with a
    running (max, sum-exp, accumulator) online softmax.
    """
    q = q_ref[0].astype(jnp.float32)  # [BQ, Dh]
    bq = q.shape[0]
    dh = q.shape[1]

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * block_k
        k = k_ref[0, pl.dslice(start, block_k), :].astype(jnp.float32)  # [BK, Dh]
        v = v_ref[0, pl.dslice(start, block_k), :].astype(jnp.float32)  # [BK, Dh]
        msk = mask_ref[0, :, pl.dslice(start, block_k)].astype(jnp.float32)  # [BQ, BK]
        s = q @ k.T * scale + (1.0 - msk) * NEG_INF  # [BQ, BK]
        m_cur = jnp.max(s, axis=-1)  # [BQ]
        m_new = jnp.maximum(m_prev, m_cur)
        # Keep fully-masked rows stable: exp(NEG_INF - NEG_INF) would be 1,
        # so gate by the mask tile explicitly.
        p = jnp.exp(s - m_new[:, None]) * (msk > 0)  # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (block shapes must tile N)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def masked_attention_pallas(q, k, v, mask, block_q: int = 32, block_k: int = 64):
    """Pallas forward: softmax(q k^T * scale + mask_bias) v.

    Shapes: q,k,v [B,H,N,Dh]; mask [B,N,N] with 1=may-attend.
    """
    b, h, n, dh = q.shape
    bq = _pick_block(n, block_q)
    bk = _pick_block(n, block_k)
    scale = 1.0 / float(dh) ** 0.5
    bh = b * h

    qf = q.reshape(bh, n, dh)
    kf = k.reshape(bh, n, dh)
    vf = v.reshape(bh, n, dh)

    kernel = functools.partial(_attn_kernel, block_k=bk, n_kv=n // bk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(bh, n // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, qi: (g, qi, 0)),  # q tile
            pl.BlockSpec((1, n, dh), lambda g, qi: (g, 0, 0)),  # k rows
            pl.BlockSpec((1, n, dh), lambda g, qi: (g, 0, 0)),  # v rows
            # mask is per-batch (shared across heads): index by g // h.
            pl.BlockSpec((1, bq, n), lambda g, qi, h=h: (g // h, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda g, qi: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf, mask)
    return out.reshape(b, h, n, dh)


@jax.custom_vjp
def masked_attention(q, k, v, mask):
    """Masked attention: Pallas forward, oracle-derived exact backward."""
    return masked_attention_pallas(q, k, v, mask)


def _fwd(q, k, v, mask):
    return masked_attention_pallas(q, k, v, mask), (q, k, v, mask)


def _bwd(res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(masked_attention_ref, q, k, v, mask)
    dq, dk, dv, _ = vjp(g)
    return dq, dk, dv, None


masked_attention.defvjp(_fwd, _bwd)
