"""Golden cross-language mask fixtures (numpy only — no jax import, so
`make fixtures` runs anywhere the python tests do).

Generates artifacts/fixtures/masks.json, which is COMMITTED to the repo:
`cargo test` byte-compares the rust builders (rust/src/model/mask.rs)
against it on every run, and the python suite compares the on-device
constructor reference (masks.masks_from_order) against the same dense
builders — so the rust path, the python reference, and the device-side
construction are all pinned to one artifact and cannot silently diverge.

Schema: a JSON array of cases
  {"n", "m", "visible", "sigma",
   "verify_h": [n*n], "verify_g": [n*n],
   "drafts": [{"n_known": k, "h": [n*n], "g": [n*n]}, ...]}
with the draft sweep covering the endpoints (k = m, k = n) plus sampled
interior states for every sigma — lattice orderings and arbitrary
permutations (the Fig. 3 ablation path) alike.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

try:
    from . import masks as masks_mod
except ImportError:  # invoked as a script: `python3 python/compile/fixtures.py`
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from compile import masks as masks_mod


def _draft_sweep(rng: np.random.Generator, m: int, n: int) -> list:
    """Endpoint states plus up to 3 sampled interior states."""
    ks = {m, n}
    if n - m > 1:
        ks.update(int(k) for k in rng.integers(m, n + 1, size=3))
    return sorted(ks)


def _case(sigma: list, m: int, vis: list, rng: np.random.Generator) -> dict:
    n = len(sigma)
    mh, mg = masks_mod.verify_masks(sigma, m)
    drafts = []
    for k in _draft_sweep(rng, m, n):
        dh, dg = masks_mod.draft_masks(sigma, m, k)
        drafts.append(
            {
                "n_known": k,
                "h": dh.astype(int).flatten().tolist(),
                "g": dg.astype(int).flatten().tolist(),
            }
        )
    return {
        "n": n,
        "m": m,
        "visible": vis,
        "sigma": sigma,
        "verify_h": mh.astype(int).flatten().tolist(),
        "verify_g": mg.astype(int).flatten().tolist(),
        "drafts": drafts,
    }


def export_mask_fixtures(cfg, path: str, seed: int = 1234) -> None:
    """Golden fixtures: rust mask builders must match these bit-for-bit.

    `cfg` is accepted (and ignored) for aot.py signature compatibility —
    fixture shapes are deliberately independent of any model config.
    """
    del cfg
    rng = np.random.default_rng(seed)
    cases = []
    for _ in range(8):
        n = int(rng.integers(4, 17))
        m = int(rng.integers(1, n))
        vis = sorted(rng.choice(n, size=m, replace=False).tolist())
        sigma = masks_mod.lattice_sigma(vis, n)
        cases.append(_case(sigma, m, vis, rng))
    # Arbitrary-permutation (non-lattice) cases for the Fig. 3 ablation
    # path — the draft sweep applies to these too.
    for _ in range(4):
        n = int(rng.integers(4, 13))
        m = int(rng.integers(1, n))
        sigma = rng.permutation(n).tolist()
        cases.append(_case(sigma, m, sorted(sigma[:m]), rng))
    with open(path, "w") as f:
        json.dump(cases, f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/fixtures/masks.json")
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    export_mask_fixtures(None, args.out, args.seed)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
