# asarm build/verify entry points.
#
# `make verify` is the gate every PR must pass: the tier-1 build + tests
# (ROADMAP.md) plus the documentation surface — rustdoc with warnings
# denied and rustfmt in check mode — so docs and formatting cannot rot.

.PHONY: all build test doc fmt verify artifacts models bench bench-smoke

all: build

build:
	cargo build --release

test:
	cargo test -q

# Docs are part of the verify path: broken intra-doc links or malformed
# rustdoc fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt --check

verify: build test doc fmt

# Python runs exactly once: AOT-lower the AS-ARM (Pallas kernels) to HLO
# text artifacts consumed by the rust runtime.
artifacts:
	python3 python/compile/aot.py --out-dir artifacts

# Train the stories checkpoint the examples and serve_e2e load.
models:
	cargo run --release -- train --artifacts artifacts --corpus stories \
		--out artifacts/ckpt_stories_ft.bin

bench:
	cargo bench --bench perf_coordinator
	cargo bench --bench perf_engine

# Tiny Table-1 run (drafter sweep included) on the analytic mock engine:
# no artifacts or checkpoint needed, finishes in seconds. CI smoke.
bench-smoke:
	ASARM_BENCH_MOCK=1 ASARM_BENCH_SEQS=2 cargo bench --bench table1_assd
