# asarm build/verify entry points.
#
# `make verify` is the gate every PR must pass: the tier-1 build + tests
# (ROADMAP.md) plus the documentation surface — rustdoc with warnings
# denied, rustfmt in check mode, and clippy with warnings denied — so
# docs, formatting, and lints cannot rot.

.PHONY: all build test doc fmt lint verify artifacts fixtures models bench bench-smoke chaos

all: build

build:
	cargo build --release

test:
	cargo test -q

# Docs are part of the verify path: broken intra-doc links or malformed
# rustdoc fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt --check

# Lints cover every target (benches and examples included) so the perf
# gates cannot drift out of compilability between bench runs.
lint:
	cargo clippy --all-targets -- -D warnings

verify: build test doc fmt lint

# Python runs exactly once: AOT-lower the AS-ARM (Pallas kernels) to HLO
# text artifacts consumed by the rust runtime (dense fwd_b{B} AND compact
# fwd_ord_b{B} families — see docs/ARCHITECTURE.md §Compact forward ABI).
# (module invocation: aot.py uses package-relative imports, so running it
# as a plain script fails with "attempted relative import")
artifacts:
	PYTHONPATH=python python3 -m compile.aot --out-dir artifacts

# Regenerate the committed golden mask fixtures (numpy only, no jax):
# the cross-language parity test `golden_fixtures_match_python` pins the
# rust builders and the on-device construction to this file.
fixtures:
	python3 python/compile/fixtures.py --out artifacts/fixtures/masks.json

# Train the stories checkpoint the examples and serve_e2e load.
models:
	cargo run --release -- train --artifacts artifacts --corpus stories \
		--out artifacts/ckpt_stories_ft.bin

bench:
	cargo bench --bench perf_coordinator
	cargo bench --bench perf_engine
	cargo bench --bench perf_streaming
	cargo bench --bench perf_paged

# Tiny Table-1 run (drafter sweep included) plus the compact-vs-dense
# forward-ABI ablation, the incremental-vs-compact KV-cache ablation, and
# the streaming-lifecycle TTFT/ITL sweep, all on the analytic mock
# engine: no artifacts or checkpoint needed, finishes in seconds. CI
# smoke — perf_engine writes BENCH_engine.json + BENCH_incremental.json
# and exits non-zero if the compact path regresses tokens/sec vs dense,
# if the incremental path regresses vs compact (or its modeled
# per-iteration compute stops beating compact's), or any paths' outputs
# diverge; perf_streaming writes BENCH_streaming.json and exits non-zero
# if streaming TTFT stops beating the blocking path's total latency;
# perf_paged writes BENCH_paged.json (slab-vs-paged memory model,
# warm-vs-cold TTFT proxy, prefix-cache hit-rate sweep) and exits
# non-zero if the warm first iteration stops beating the cold one, warm
# outputs diverge, repeated prompts stop hitting the cache, or the paged
# peak footprint exceeds the slab layout it replaced.
#
# The BENCH_*.json files land at the REPO ROOT (cargo bench runs from
# here) and are COMMITTED, so the perf trajectory is tracked in-tree
# across PRs instead of living only in CI artifacts: after a bench run
# with meaningful changes, `git add BENCH_*.json`.
#
# perf_streaming and perf_paged additionally dump one traced request
# each as TRACE_streaming.json / TRACE_paged.json — Chrome trace-event
# JSON (the same bytes GET /trace/{id} serves), loadable into
# chrome://tracing or Perfetto. Those are ephemeral inspection aids
# (uploaded from CI, gitignored here), not committed baselines.
# perf_coordinator additionally gates tracing overhead: it exits
# non-zero if tracing-on throughput drops below 0.95x tracing-off.
bench-smoke:
	ASARM_BENCH_MOCK=1 ASARM_BENCH_SEQS=2 cargo bench --bench table1_assd
	ASARM_BENCH_MOCK=1 cargo bench --bench perf_engine
	cargo bench --bench perf_streaming
	cargo bench --bench perf_paged

# Deterministic chaos soak (docs/ARCHITECTURE.md §Fault tolerance &
# supervision): seeded fault injection across every decode mode,
# asserting bit-identity with the fault-free run, intact NFE bounds,
# and supervised replica restart. The seed is pinned so CI and local
# runs see the same fault schedule; override to explore:
#   make chaos ASARM_CHAOS_SEED=12345
# On divergence the suite leaves TRACE_chaos.json (Chrome trace of the
# last chaos request) at the repo root for CI to upload.
ASARM_CHAOS_SEED ?= 20260808
chaos:
	ASARM_CHAOS_SEED=$(ASARM_CHAOS_SEED) cargo test --release --test chaos_soak -- --nocapture
